"""ShardedPandaDB: the cluster coordinator (paper §VII-A serving layer).

Owns N shard replicas -- each a full :class:`~repro.core.database.PandaDB`
over a hash-partitioned slice (see :mod:`repro.cluster.partition` for the
layout rules) -- and routes every statement:

* **kNN** scatter-gathers through the one shared merge schedule
  (:func:`repro.core.vector_index.scatter_gather_knn`): per-shard ADC or
  float scan (each shard's cost model picks, from its own observed
  throughputs), ``merge_topk`` reduce, shard-padding truncation.  Exact
  re-ranked scores merge exactly, so results are byte-identical to a
  single-node index over the same corpus.
* **point lookups / id-bound MATCHes** route to the owner shard only; the
  cost model's ``choose_shard_route`` prefers the routed plan over the
  (also correct, but P-dispatch) fan-out whenever the predicate pins an
  owner.
* **label / all-node scans** fan out to every shard and stream through an
  ordered merge that restores the global row order and preserves ``LIMIT``
  early exit end-to-end (per-shard caps + merged cap + pipeline close).

Sessions (:class:`ClusterSession`) mirror the driver surface
(``prepare()``/``run()``/cursors) and all shards share ONE plan cache:
parse+optimize runs once per query skeleton for the whole cluster, and any
shard's epoch-invalidation semantics apply unchanged because plans are
db-independent trees.  :class:`~repro.serving.engine.QueryServer` accepts a
``ShardedPandaDB`` wherever it accepts a ``PandaDB``.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.pandadb import PandaDBConfig, VectorIndexConfig
from repro.core import logical_plan as lp
from repro.core.cost_model import StatisticsService, estimate_plan_cost
from repro.core.cypherplus import (
    CreateQuery,
    FuncCall,
    Literal,
    MatchQuery,
    Param,
    parse_query,
    query_params,
)
from repro.core.aipm import proxy_key
from repro.core.database import PandaDB
from repro.core.deadline import Deadline
from repro.core.executor import (
    DEFAULT_BATCH_ROWS,
    ExecutionContext,
    execute_iter,
    execute_iter_tagged,
)
from repro.core.session import (
    Cursor,
    PlanCache,
    RWLock,
    _projection_keys,
    bind_text,
    check_wal_renderable,
    plan_query,
    skeleton_of,
)
from repro.core.vector_index import IVFIndex, scatter_gather_knn
from repro.obs import MetricsRegistry, QueryProfile, Tracer
from repro.obs.trace import Trace
from repro.cluster.partition import ShardMap, make_shard
from repro.cluster.scatter import (
    ClusterUnsupportedQuery,
    close_streams,
    fanout_anchor,
    id_bound_expr,
    ordered_merge,
    resolve_id,
)
from repro.graphstore.blob import Blob
from repro.graphstore.wal import WriteAheadLog


@dataclasses.dataclass(frozen=True)
class _PendingBlob:
    """Blob content + resolved mime, carried from statement resolution to
    owner-shard registration (so cluster CREATEs keep the same blob
    metadata a single-node apply would record)."""
    content: bytes
    mime: str


# -- shard-side write ops -----------------------------------------------------
#
# Every coordinator write is expressed as a named op applied to one shard
# db.  The base coordinator dispatches directly; the replicated coordinator
# records the same (op, args, kwargs) tuple on the shard's op log (the
# leader-WAL path) and applies it to every live replica, so a revived
# replica replays exactly what it missed.

def _create_node_slot(db: PandaDB, nid: int, label: str,
                      scalar_props: Dict[str, Any],
                      blob_specs: Dict[str, Tuple[int, bytes, str]],
                      owned: bool) -> int:
    """One shard's (or replica's) view of a cluster create_node: the label
    slot always, scalar props + blob payload only on the owner."""
    props: Dict[str, Any] = dict(scalar_props)
    for k, (bid, content, mime) in blob_specs.items():
        props[k] = db.graph.blobs.create(content, mime, blob_id=bid)
    got = db.graph.create_node(label, **props)
    assert got == nid, (got, nid)
    db.graph.store.set_owner(nid, owned)
    return nid


def _adopt_node(db: PandaDB, nid: int, scalar_props: Dict[str, Any],
                blob_specs: Dict[str, Tuple[int, bytes, str]],
                out_edges: List[Tuple[int, str, Dict[str, Any]]]) -> int:
    """Rebalance landing path: the slot already exists everywhere; install
    the shipped property payload + blob content + co-located out-edges and
    take ownership."""
    for k, v in scalar_props.items():
        db.graph.store.node_props.set(nid, k, v)
    for k, (bid, content, mime) in blob_specs.items():
        db.graph.blobs.create(content, mime, blob_id=bid)
        db.graph.store.node_props.set(nid, k, bid, kind="blob")
    for tgt, rel_type, rprops in out_edges:
        db.graph.create_relationship(nid, tgt, rel_type, log=False, **rprops)
    db.graph.store.set_owner(nid, True)
    return nid


def _copy_piece(piece: IVFIndex) -> IVFIndex:
    """A replica-private view of one index piece: shares the (immutable
    once compacted) arrays but owns its append buffers, so replicas can
    absorb DynamicIndexing inserts independently."""
    piece.compact()
    return dataclasses.replace(piece, _pend_vecs={}, _pend_ids={},
                               _pend_codes={}, _pend_bias={},
                               pending_count=0,
                               scan_rows=0, scan_time=0.0)


def _apply_op(db: PandaDB, op: str, args: tuple, kw: Dict[str, Any]) -> Any:
    if op == "create_node":
        return _create_node_slot(db, *args)
    if op == "create_rel":
        return db.graph.create_relationship(*args, **kw)
    if op == "register_extractor":
        return db.register_extractor(*args, **kw)
    if op == "register_proxy":
        return db.register_proxy(*args, **kw)
    if op == "set_calibration":
        sub_key, es, ps, scores, labels = args
        db.calibrator.set_curve(sub_key, es, ps, scores, labels)
        db.stats.epoch += 1      # cascade path unlocked: re-optimize plans
        return None
    if op == "index_insert":
        return db.index_insert(*args)
    if op == "set_index":
        sub_key, piece = args
        db.indexes[sub_key] = _copy_piece(piece)
        db.stats.note_index_rebuild(sub_key)
        return db.indexes[sub_key]
    if op == "set_owner":
        nid, owned = args
        db.graph.store.set_owner(nid, owned)
        return None
    if op == "adopt_node":
        return _adopt_node(db, *args)
    if op == "drop_blob":
        db.graph.blobs.delete(args[0])
        return None
    raise ValueError(f"unknown shard op {op!r}")


class ClusterCursor(Cursor):
    """A :class:`~repro.core.session.Cursor` over an already-routed row
    stream (merged fan-out or a single shard's pipeline).  Inherits the
    fetch surface; closing tears the shard pipelines down."""

    def __init__(self, gen, keys: Tuple[str, ...] = (),
                 rwlock: Optional[RWLock] = None, deadline=None,
                 trace: Optional[Trace] = None,
                 profile: Optional[QueryProfile] = None,
                 plan: Optional[lp.PlanOp] = None) -> None:
        super().__init__(None, None, keys=tuple(keys), rwlock=rwlock)
        if gen is not None:
            self._gen = gen
            self._exhausted = False
        self._closed = gen is None
        # the statement's shared budget: surfaces degradations/approximate
        # through the inherited Cursor properties (no ctx on the merge side)
        self._deadline = deadline
        # trace/profile installed after super().__init__ (which would treat
        # the plan-less base cursor as exhausted and finish the trace early)
        self.trace = trace
        self._profile = profile
        self._profile_plan = plan
        if gen is None and trace is not None:
            trace.finish()

    def close(self) -> None:
        """Exception-safe teardown: whatever ``_gen.close()`` does (a shard
        erroring during its φ-cancelling close included), this cursor ends
        up closed and re-closing is a no-op."""
        if self._closed:
            return
        try:
            super().close()
        finally:
            self._closed = True
            self._exhausted = True
            self._buf.clear()


class ClusterPreparedStatement:
    """Parsed once; each ``run()`` re-routes (a ``$id`` binding may move
    the owner shard) but reuses the cluster-shared cached plan."""

    def __init__(self, session: "ClusterSession", text: str) -> None:
        self.session = session
        self.text = text
        self.skeleton = skeleton_of(text)
        self.query = parse_query(text)
        self.param_names = frozenset(query_params(self.query))

    def run(self, parameters: Optional[Dict[str, Any]] = None,
            optimized: bool = True,
            deadline_ms: Optional[float] = None,
            profile: bool = False, **params: Any) -> ClusterCursor:
        return self.session._run_parsed(self.skeleton, self.query,
                                        {**(parameters or {}), **params},
                                        optimized=optimized, text=self.text,
                                        deadline_ms=deadline_ms,
                                        profile=profile)


class ClusterSession:
    """One client's conversation with the cluster; the serving workers'
    handle.  API-compatible with :class:`~repro.core.session.Session` for
    the read/write statement surface (``prepare()``/``run()``/cursors)."""

    def __init__(self, cdb: "ShardedPandaDB",
                 batch_rows: int = DEFAULT_BATCH_ROWS,
                 use_cache: bool = True,
                 prefetch_depth: Optional[int] = None,
                 deadline_ms: Optional[float] = None) -> None:
        self.cdb = cdb
        self.batch_rows = batch_rows
        self.use_cache = use_cache
        self.prefetch_depth = prefetch_depth
        #: default per-query budget (run(deadline_ms=) overrides;
        #: ClusterConfig.default_deadline_ms backstops both)
        self.deadline_ms = deadline_ms
        self._closed = False
        self._cursors: List[ClusterCursor] = []

    def __enter__(self) -> "ClusterSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Close the session AND every cursor it handed out: an abandoned
        mid-iteration cursor still tears its shard pipelines down (each
        close attempted even if an earlier one raises; first error
        re-raised)."""
        self._closed = True
        cursors, self._cursors = self._cursors, []
        first: Optional[BaseException] = None
        for cur in cursors:
            try:
                cur.close()
            except BaseException as e:  # noqa: BLE001 -- visit every cursor
                if first is None:
                    first = e
        if first is not None:
            raise first

    def _track(self, cur: ClusterCursor) -> ClusterCursor:
        # prune finished cursors so long-lived serving sessions stay O(open)
        self._cursors = [c for c in self._cursors
                         if not (c._closed or c._exhausted)]
        if not cur._closed:
            self._cursors.append(cur)
        return cur

    def prepare(self, text: str) -> ClusterPreparedStatement:
        return ClusterPreparedStatement(self, text)

    def run(self, text: str, parameters: Optional[Dict[str, Any]] = None,
            optimized: bool = True,
            deadline_ms: Optional[float] = None,
            profile: bool = False, trace: Optional[Trace] = None,
            **params: Any) -> ClusterCursor:
        if self._closed:
            raise RuntimeError("session is closed")
        params = {**(parameters or {}), **params}
        return self._run_parsed(skeleton_of(text), parse_query(text), params,
                                optimized=optimized, text=text,
                                deadline_ms=deadline_ms,
                                profile=profile, trace=trace)

    def _run_parsed(self, skeleton: str, q, params: Dict[str, Any],
                    optimized: bool, text: str,
                    deadline_ms: Optional[float] = None,
                    profile: bool = False,
                    trace: Optional[Trace] = None) -> ClusterCursor:
        if self._closed:
            raise RuntimeError("session is closed")
        cdb = self.cdb
        missing = query_params(q) - set(params)
        if missing:
            raise KeyError(f"unbound parameters: "
                           f"{', '.join('$' + m for m in sorted(missing))}")
        profile = profile or bool(getattr(q, "profile", False))
        if trace is None:
            trace = cdb.tracer.begin("query", force=profile,
                                     skeleton=skeleton)
        # ONE Deadline object for the whole statement: every shard leg,
        # hedge race and retry below clamps to the same remaining budget
        deadline = Deadline.resolve(deadline_ms, self.deadline_ms,
                                    cdb.cfg.cluster.default_deadline_ms)
        if isinstance(q, CreateQuery):
            cdb.rwlock.acquire_write()
            try:
                cdb._execute_create(q, text, params)
            finally:
                cdb.rwlock.release_write()
            return ClusterCursor(None, trace=trace)
        if trace is None:
            plan = cdb._plan_cached(skeleton, q, optimized,
                                    use_cache=self.use_cache)
        else:
            with trace.span("plan") as sp:
                misses0 = cdb.plan_cache.misses
                plan = cdb._plan_cached(skeleton, q, optimized,
                                        use_cache=self.use_cache)
                sp.set(cache="off" if not self.use_cache else
                       "miss" if cdb.plan_cache.misses > misses0 else "hit")
        qprof: Optional[QueryProfile] = None
        if profile:
            qprof = QueryProfile()
            qprof.capture_predictions(plan, cdb.lead_db().stats)
        route, owner, anchor = cdb._route(q, plan, params)
        if trace is not None:
            trace.event("route", choice=route, anchor=anchor,
                        owner=-1 if owner is None else owner)
        keys = _projection_keys(q)
        if route == "routed":
            if qprof is not None:
                qprof.note_shard(owner)
            ctx = ExecutionContext(cdb.read_db(owner), params,
                                   prefetch_depth=self.prefetch_depth,
                                   deadline=deadline,
                                   trace=trace, profile=qprof)
            return self._track(
                ClusterCursor(execute_iter(plan, ctx, self.batch_rows),
                              keys=keys, rwlock=cdb.rwlock,
                              deadline=deadline, trace=trace,
                              profile=qprof, plan=plan))
        limit = _root_limit(plan, params)
        streams: List[Any] = []
        try:
            for s in cdb.active:
                streams.append(cdb._shard_stream(
                    plan, s, params, anchor, self.batch_rows, limit,
                    self.prefetch_depth, deadline=deadline,
                    trace=trace, profile=qprof))
        except BaseException:
            # a later shard failing to open must not leak the earlier
            # shards' pipelines
            close_streams(streams)
            raise
        gen = ordered_merge(streams,
                            batch_rows=cdb.cfg.cluster.merge_batch_rows,
                            limit=limit)
        return self._track(ClusterCursor(gen, keys=keys, rwlock=cdb.rwlock,
                                         deadline=deadline, trace=trace,
                                         profile=qprof, plan=plan))

    def explain(self, text: str) -> Dict[str, Any]:
        return self.cdb.explain(text)


def _root_limit(plan: lp.PlanOp, params: Dict[str, Any]) -> Optional[int]:
    if not isinstance(plan, lp.Limit):
        return None
    n = plan.n
    if isinstance(n, Param):
        n = params[n.name]
    return int(n)


class ShardedPandaDB:
    """Coordinator over ``n_shards`` hash-partitioned PandaDB replicas."""

    def __init__(self, n_shards: Optional[int] = None,
                 cfg: Optional[PandaDBConfig] = None,
                 owner_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None
                 ) -> None:
        self.cfg = cfg or PandaDBConfig()
        self.n_shards = int(n_shards or self.cfg.cluster.n_shards)
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        #: the versioned node->shard assignment; its epoch joins the plan
        #: cache key so topology changes invalidate cached plans
        self.shard_map = ShardMap(self.n_shards, owner_fn)
        self.owner_fn = self.shard_map.owner
        self.shards: List[PandaDB] = self._make_shards()
        #: ONE plan cache for the whole cluster: any worker's prepared
        #: skeleton serves every shard (plans are db-independent trees)
        self.plan_cache = PlanCache()
        for sh in self.shards:
            sh.plan_cache = self.plan_cache
        #: coordinator statistics: per-shard scan EWMAs + fan-out terms
        self.stats = StatisticsService(self.cfg.cost)
        self.rwlock = RWLock()
        self.wal = WriteAheadLog(None)    # leader statement log (§VII-A)
        self._blob_owner: Dict[int, int] = {}
        self._next_blob_id = 0
        #: unified registry: routing decisions, failure-masking counters and
        #: per-node replica reads all live here; ``route_counts`` /
        #: ``cluster_counters()`` below are byte-compatible read views
        self.metrics = MetricsRegistry("cluster")
        for name in ("hedges_fired", "hedges_won", "retries", "failovers",
                     "rebalance_moves", "teardown_errors", "degraded"):
            self.metrics.counter(name)
        self.metrics.counter("route_routed")
        self.metrics.counter("route_fanout")
        self.tracer = Tracer(enabled=self.cfg.obs.trace,
                             keep_last=self.cfg.obs.trace_keep_last)
        self._pool: Optional[ThreadPoolExecutor] = None
        if self.cfg.cluster.parallel_fanout and self.n_shards > 1:
            self._pool = ThreadPoolExecutor(
                max_workers=self.n_shards,
                thread_name_prefix="shard-scatter")
        self._default_session: Optional[ClusterSession] = None

    def _make_shards(self) -> List[PandaDB]:
        """One PandaDB per shard; the replicated coordinator overrides this
        to build replica sets and return the primaries."""
        return [make_shard(self.cfg) for _ in range(self.n_shards)]

    # -- lifecycle ------------------------------------------------------------

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    @property
    def n_nodes(self) -> int:
        return self.lead_db().graph.store.n_nodes

    @property
    def active(self) -> List[int]:
        """Shard ids currently serving (a recovered-away shard drops out)."""
        return list(self.shard_map.active)

    def owner_of(self, node_id: int) -> int:
        return int(self.owner_fn(np.asarray([node_id], np.int64))[0])

    # -- replica hooks (the replicated coordinator overrides these) -----------

    def read_db(self, s: int) -> PandaDB:
        """The db answering shard ``s``'s reads right now."""
        return self.shards[s]

    def lead_db(self) -> PandaDB:
        """A live db for planning / statistics (any shard works: structure
        and registry serials are replicated)."""
        return self.read_db(self.shard_map.active[0])

    def _shard_apply(self, s: int, op: str, *args: Any, **kw: Any) -> Any:
        """Apply one write op to shard ``s`` (all its live replicas, once
        replicated)."""
        return _apply_op(self.shards[s], op, args, kw)

    def _shard_stream(self, plan: lp.PlanOp, s: int, params: Dict[str, Any],
                      anchor: str, batch_rows: int, limit: Optional[int],
                      prefetch_depth: Optional[int], deadline=None,
                      trace=None, profile=None):
        """One shard's tagged fan-out stream (replicated: hedged +
        failover-wrapped).  ``deadline`` is the statement's shared budget
        (every shard leg clamps to the same remaining time); ``trace`` /
        ``profile`` are the statement's shared span tree and PROFILE
        accumulator (per-node operator times sum across shards because
        every leg executes the same plan tree)."""
        if profile is not None:
            profile.note_shard(s)
        ctx = ExecutionContext(self.shards[s], params,
                               prefetch_depth=prefetch_depth,
                               deadline=deadline,
                               trace=trace, profile=profile)
        return execute_iter_tagged(plan, ctx, anchor, batch_rows,
                                   limit=limit)

    def _count(self, name: str, n: int = 1) -> None:
        self.metrics.counter(name).inc(n)

    def _count_replica_read(self, s: int, r: int) -> None:
        self.metrics.counter(f"replica_reads:s{s}r{r}").inc()

    @property
    def route_counts(self) -> Dict[str, int]:
        """Routed-vs-fanout statement counts (registry-backed; still reads
        like the old plain dict: ``c.route_counts["routed"]``)."""
        return {"routed": self.metrics.counter("route_routed").value,
                "fanout": self.metrics.counter("route_fanout").value}

    def cluster_counters(self) -> Dict[str, int]:
        """Hedges fired/won, retries, failovers, rebalance moves and
        per-node replica reads -- chaos tests assert on these instead of
        timing.  A registry read, shaped exactly like the old counter
        dicts."""
        out: Dict[str, int] = {}
        reads: Dict[str, int] = {}
        for name, v in self.metrics.counters_view().items():
            if name.startswith("route_"):
                continue
            if name.startswith("replica_reads:"):
                reads[name] = v
            else:
                out[name] = v
        for key in sorted(reads):
            out[key] = reads[key]
        return out

    # -- data path (routed writes) --------------------------------------------

    def create_node(self, label: str, **props: Any) -> int:
        """Create one node cluster-wide: the label slot is replicated on
        every shard (structure), properties and blob payload land on the
        owner only.  Blob ids come from the coordinator's global sequence
        so they are identical to a single-node database fed the same
        creation order."""
        nid = self.n_nodes
        owner = self.owner_of(nid)
        scalar: Dict[str, Any] = {}
        blob_specs: Dict[str, Tuple[int, bytes, str]] = {}
        for k, v in props.items():
            if isinstance(v, Blob):
                # a Blob handle points into ONE shard's (or a single-node
                # db's) store; accepting it would leave the content
                # unreachable from the owner and jump the coordinator's
                # global id sequence into the shards' temp range
                raise TypeError(
                    f"property {k!r}: pass blob content (bytes / ndarray), "
                    f"not a Blob handle -- cluster blob ids are assigned by "
                    f"the coordinator")
            if isinstance(v, (bytes, np.ndarray, _PendingBlob)):
                if isinstance(v, _PendingBlob):
                    content, mime = v.content, v.mime
                else:
                    content, mime = \
                        self.lead_db().graph.blobs.resolve_source(v)
                bid = self._next_blob_id
                blob_specs[k] = (bid, content, mime)
                self._blob_owner[bid] = owner
                self._next_blob_id = bid + 1
            else:
                scalar[k] = v
        for s in self.active:
            self._shard_apply(s, "create_node", nid, label,
                              scalar if s == owner else {},
                              blob_specs if s == owner else {},
                              s == owner)
        return nid

    def create_relationship(self, src: int, dst: int, rel_type: str,
                            **props: Any) -> int:
        """Edges are co-located with their source node's shard."""
        return self._shard_apply(self.owner_of(src), "create_rel",
                                 src, dst, rel_type, **props)

    def register_extractor(self, sub_key: str, fn, batch_size: int = 64) -> int:
        """Models are replicated: every shard extracts φ for its own slice
        (and for query-side blobs), so serials stay aligned cluster-wide."""
        serial = 0
        for s in self.active:
            serial = self._shard_apply(s, "register_extractor", sub_key, fn,
                                       batch_size)
        return serial

    def register_proxy(self, sub_key: str, fn, batch_size: int = 256) -> int:
        """Proxy tiers replicate like extractors: every shard scores its own
        slice, so proxy serials (and hence cascade cache/calibration keys)
        stay aligned cluster-wide."""
        serial = 0
        for s in self.active:
            serial = self._shard_apply(s, "register_proxy", sub_key, fn,
                                       batch_size)
        return serial

    def calibrate_cascade(self, sub_key: str, prop_key: str,
                          sample: Optional[int] = None,
                          pairs: Optional[int] = None,
                          seed: Optional[int] = None):
        """Cluster cascade calibration, the ``build_index`` pattern: gather
        every shard's owned blob ids, sort globally (the exact single-node
        sampling input, so the seeded sample -- and therefore the fitted
        curve -- is bit-identical to ``PandaDB.calibrate_cascade`` on the
        same data), extract both tiers on the owner shards, fit ONE curve,
        and install it on every shard via the replayable ``set_calibration``
        op.  Every shard then derives identical thresholds for any target."""
        from repro.core.cascade import curve_from_vectors
        from repro.core.executor import SIM_THRESHOLD

        ccfg = self.cfg.cascade
        sample = ccfg.calibration_sample if sample is None else sample
        pairs = ccfg.calibration_pairs if pairs is None else pairs
        seed = ccfg.calibration_seed if seed is None else seed
        per_bids: Dict[int, np.ndarray] = {}
        column_seen = False
        for s in self.active:
            try:
                per_bids[s] = self.read_db(s).blob_ids_for(prop_key)
                column_seen = True
            except KeyError:
                per_bids[s] = np.empty(0, np.int64)
        if not column_seen:
            raise KeyError(f"no property {prop_key!r}")
        all_bids = np.sort(np.concatenate(list(per_bids.values())))
        if all_bids.size == 0:
            raise ValueError(f"no blobs under property {prop_key!r}")
        rng = np.random.default_rng(seed)
        if len(all_bids) > sample:
            pick = rng.choice(len(all_bids), size=sample, replace=False)
            all_bids = all_bids[np.sort(pick)]
        exact: Dict[int, Any] = {}
        prox: Dict[int, Any] = {}
        for s in self.active:
            sh = self.read_db(s)
            mine = all_bids[np.isin(all_bids, per_bids[s])]
            if mine.size == 0:
                continue
            for b, v in zip(mine, sh.phi_for_blobs(sub_key, mine)):
                exact[int(b)] = v
            for b, v in zip(mine, sh.proxy_for_blobs(sub_key, mine)):
                prox[int(b)] = v
        exact_vecs = np.stack([exact[int(b)] for b in all_bids])
        prox_vecs = np.stack([prox[int(b)] for b in all_bids])
        scores, labels = curve_from_vectors(exact_vecs, prox_vecs, pairs,
                                            seed, SIM_THRESHOLD)
        lead = self.lead_db()
        es = lead.registry.serial(sub_key)
        ps = lead.registry.serial(proxy_key(sub_key))
        for s in self.active:
            self._shard_apply(s, "set_calibration", sub_key, es, ps,
                              scores, labels)
        return lead.calibrator.thresholds(sub_key, es, ps, 0.95)

    # -- indexing ---------------------------------------------------------------

    def build_index(self, sub_key: str, prop_key: str,
                    cfg: Optional[VectorIndexConfig] = None
                    ) -> List[IVFIndex]:
        """Cluster BatchIndexing: each shard extracts φ for its owned blobs,
        the coordinator trains ONE set of centroids + PQ codebooks over the
        gathered space (sorted by blob id -- the exact single-node build
        input, so centroids/codes are bit-identical), then hands every
        shard its owner-assigned bucket contents via ``IVFIndex.shard``."""
        per: List[Tuple[np.ndarray, List[Any], int]] = []
        column_seen = False
        for s in self.active:
            sh = self.read_db(s)
            try:
                bids = sh.blob_ids_for(prop_key)
                column_seen = True
            except KeyError:
                # a shard that owns no node with this property never
                # materialized the column -- it just contributes no rows
                bids = np.empty(0, np.int64)
            vecs = sh.phi_for_blobs(sub_key, bids) if len(bids) else []
            per.append((bids, vecs, s))
        if not column_seen:
            raise KeyError(f"no property {prop_key!r}")
        all_bids = np.concatenate([p[0] for p in per])
        if all_bids.size == 0:
            raise ValueError(f"no blobs under property {prop_key!r}")
        all_vecs = np.stack([v for p in per for v in p[1]])
        order = np.argsort(all_bids, kind="stable")
        all_bids = all_bids[order]
        all_vecs = all_vecs[order]
        serial = self.lead_db().registry.serial(sub_key)
        cfg = cfg or dataclasses.replace(self.cfg.index,
                                         dim=all_vecs.shape[1])
        index = IVFIndex.build(all_vecs, ids=all_bids, cfg=cfg,
                               serial=serial)
        assign = np.asarray([self._blob_owner[int(b)] for b in index.ids],
                            np.int64)
        pieces = index.shard(self.n_shards, assign=assign)
        for s in self.active:
            self._shard_apply(s, "set_index", sub_key, pieces[s])
        self.stats.note_index_rebuild(sub_key)
        return pieces

    def index_insert(self, sub_key: str, blob_id: int) -> None:
        """DynamicIndexing, routed: the blob's owner shard extracts φ (its
        cache/AIPM) and appends to ITS index piece -- membership stays
        consistent with owner-shard routing after any number of inserts."""
        owner = self._blob_owner.get(int(blob_id))
        if owner is None:
            raise KeyError(f"blob {blob_id} was not created through this "
                           f"coordinator")
        self._shard_apply(owner, "index_insert", sub_key, int(blob_id))

    def index_pieces(self, sub_key: str) -> List[IVFIndex]:
        return [self.read_db(s).indexes[sub_key] for s in self.active]

    # -- kNN scatter-gather -----------------------------------------------------

    def knn(self, sub_key: str, queries: np.ndarray, k: int,
            nprobe: Optional[int] = None, mode: str = "auto",
            rerank: bool = True, deadline_ms: Optional[float] = None,
            trace: Optional[Trace] = None
            ) -> Tuple[np.ndarray, np.ndarray]:
        """Scatter-gather kNN over every shard's index piece through the
        shared ``merge_topk`` schedule.  Each shard's scan feeds its own
        cost model (ADC-vs-float stays a per-shard decision) and the
        coordinator's per-shard throughput EWMAs
        (``stats.record_shard_scan``).  Under a ``deadline_ms`` budget,
        shards that cannot answer in time are dropped and the merge
        returns partial top-k from the shards that did (padding contract:
        dropped slots are id=-1 / -inf)."""
        deadline = Deadline.resolve(deadline_ms)
        own_trace = trace is None and self.tracer.enabled
        if own_trace:
            trace = self.tracer.begin("knn", sub_key=sub_key, k=k)
        try:
            vals, ids = scatter_gather_knn(
                self.index_pieces(sub_key), queries, k, nprobe=nprobe,
                mode=mode, rerank=rerank,
                stats=[self.read_db(s).stats for s in self.active],
                record=self.stats.record_shard_scan,
                pool=self._pool,
                split_rerank_budget=self.cfg.cluster.split_rerank_budget,
                deadline=deadline, trace=trace)
        finally:
            if own_trace and trace is not None:
                trace.finish()
        if deadline is not None and "partial_topk" in deadline.degradations:
            self._count("degraded")
        return vals, ids

    def knn_fanout_cost(self, sub_key: str, q: int = 1, k: int = 10,
                        nprobe: Optional[int] = None) -> float:
        pieces = self.index_pieces(sub_key)
        m = pieces[0].centroids.shape[0]
        return self.stats.shard_knn_fanout_cost(
            [p.n_total for p in pieces], m,
            nprobe or pieces[0].cfg.nprobe, q=q, k=k)

    # -- query path -------------------------------------------------------------

    def session(self, batch_rows: Optional[int] = None,
                use_cache: bool = True,
                prefetch_depth: Optional[int] = None,
                deadline_ms: Optional[float] = None) -> ClusterSession:
        kwargs: Dict[str, Any] = {"use_cache": use_cache,
                                  "prefetch_depth": prefetch_depth,
                                  "deadline_ms": deadline_ms}
        if batch_rows is not None:
            kwargs["batch_rows"] = batch_rows
        return ClusterSession(self, **kwargs)

    def query(self, text: str, parameters: Optional[Dict[str, Any]] = None,
              optimized: bool = True, **params: Any) -> List[Dict[str, Any]]:
        if isinstance(parameters, bool):
            parameters, optimized = None, parameters
        if self._default_session is None:
            self._default_session = self.session()
        return self._default_session.run(text, parameters,
                                         optimized=optimized,
                                         **params).fetchall()

    def explain(self, text: str) -> Dict[str, Any]:
        """Route decision + costs the coordinator would use for ``text``."""
        q = parse_query(text)
        if not isinstance(q, MatchQuery):
            raise TypeError("explain() expects a MATCH query")
        plan = self._plan_cached(skeleton_of(text), q, optimized=True)
        anchor = fanout_anchor(plan)
        routable = id_bound_expr(q, anchor) is not None
        n_active = len(self.active)
        cost = estimate_plan_cost(plan, self.lead_db().stats)
        return {
            "anchor": anchor,
            "route": self.stats.choose_shard_route(cost, n_active,
                                                   routable),
            "routed_cost": self.stats.shard_routed_cost(cost, n_active),
            "fanout_cost": self.stats.shard_fanout_cost(cost, n_active),
            "n_shards": self.n_shards,
            "active_shards": self.active,
            "shard_map_epoch": self.shard_map.epoch,
            "plan": plan.describe(),
            "plan_cache": self.plan_cache.stats(),
            "route_counts": dict(self.route_counts),
            "counters": self.cluster_counters(),
            "cascade": self.lead_db()._explain_cascade(plan),
        }

    # -- internals --------------------------------------------------------------

    def _plan_cached(self, skeleton: str, q: MatchQuery, optimized: bool,
                     use_cache: bool = True) -> lp.PlanOp:
        lead = self.lead_db()
        lead.stats.refresh_from_graph(lead.graph)
        lead.stats.refresh_extractor_stats(lead.registry)
        if not use_cache:
            return plan_query(lead, q, optimized)
        # shard_map.epoch in the key: a rebalance/retire invalidates every
        # cached plan (routing decisions bake in the topology)
        key = (skeleton, optimized, lead.stats.epoch, self.shard_map.epoch)
        _, plan = self.plan_cache.get_or_build(
            key, lambda: (q, plan_query(lead, q, optimized)))
        return plan

    def _route(self, q: MatchQuery, plan: lp.PlanOp,
               params: Dict[str, Any]) -> Tuple[str, Optional[int], str]:
        """(route, owner shard or None, anchor var).  Correctness first:
        the anchor check gates everything; the cost model then prefers the
        routed plan over the fan-out whenever the statement pins an owner
        (both are semantically valid -- non-owners would scan their slice
        and match nothing)."""
        anchor = fanout_anchor(plan)
        bound = id_bound_expr(q, anchor)
        cost = estimate_plan_cost(plan, self.lead_db().stats)
        choice = self.stats.choose_shard_route(cost, len(self.active),
                                               routable=bound is not None)
        self.metrics.counter(f"route_{choice}").inc()
        if choice == "routed":
            return "routed", self.owner_of(resolve_id(bound, params)), anchor
        return "fanout", None, anchor

    def _execute_create(self, q: CreateQuery, text: str,
                        params: Dict[str, Any]) -> None:
        """Cluster CREATE: same two-phase contract as
        ``PandaDB._execute_create`` (resolve everything, then apply), with
        node creation routed through :meth:`create_node` so slots replicate
        and payload lands on owners.  The bound statement is logged once on
        the coordinator's leader WAL."""
        params = params or {}
        check_wal_renderable(q, params)

        def resolve(v: Any) -> Any:
            if isinstance(v, Literal):
                return v.value
            if isinstance(v, Param):
                if v.name not in params:
                    raise KeyError(f"missing query parameter ${v.name}")
                return params[v.name]
            return v

        # phase 1: resolve every new node's props (blob sources read here,
        # registered only on apply) -- failures abort before any mutation
        resolved: List[List[Optional[Dict[str, Any]]]] = []
        seen_vars: set = set()
        for pat in q.patterns:
            plist: List[Optional[Dict[str, Any]]] = []
            for np_ in pat.nodes:
                if np_.var in seen_vars:
                    plist.append(None)
                    continue
                if np_.var:
                    seen_vars.add(np_.var)
                props: Dict[str, Any] = {}
                for k, v in np_.props:
                    if isinstance(v, (Literal, Param)):
                        props[k] = resolve(v)
                    elif isinstance(v, FuncCall) \
                            and v.name == "createFromSource":
                        src = resolve(v.args[0])
                        content, mime = \
                            self.lead_db().graph.blobs.resolve_source(
                                src if isinstance(src, (str, bytes))
                                else str(src))
                        # registered on the owner at apply, mime intact
                        props[k] = _PendingBlob(content, mime)
                plist.append(props)
            resolved.append(plist)

        # phase 2: apply (routed), then log once
        env: Dict[str, int] = {}
        for pat, plist in zip(q.patterns, resolved):
            prev = None
            for i, np_ in enumerate(pat.nodes):
                if np_.var in env:
                    nid = env[np_.var]
                else:
                    nid = self.create_node(np_.label or "Node",
                                           **(plist[i] or {}))
                    if np_.var:
                        env[np_.var] = nid
                if prev is not None:
                    rel = pat.rels[i - 1]
                    src, dst = ((prev, nid) if rel.direction != "in"
                                else (nid, prev))
                    self.create_relationship(src, dst, rel.rel_type or "REL")
                prev = nid
        self.wal.append(bind_text(text, params))
