"""Sharded cluster subsystem (paper §VII-A): hash-partitioned shards,
replica sets with hedged/failover reads, scatter-gather + routed query
serving, and live rebalancing."""
from repro.cluster.coordinator import (
    ClusterCursor,
    ClusterPreparedStatement,
    ClusterSession,
    ShardedPandaDB,
)
from repro.cluster.partition import (
    TEMP_BLOB_BASE,
    ShardMap,
    default_owner_fn,
    make_shard,
    owner_shard,
    stable_id_hash,
)
from repro.cluster.rebalance import Move, Rebalancer
from repro.cluster.replication import (
    FaultInjector,
    ReplicaDown,
    ReplicaError,
    ReplicaSet,
    ReplicatedPandaDB,
    hedged_call,
    resilient_stream,
)
from repro.cluster.scatter import (
    ClusterUnsupportedQuery,
    close_streams,
    fanout_anchor,
    id_bound_expr,
    ordered_merge,
)

__all__ = [
    "ClusterCursor",
    "ClusterPreparedStatement",
    "ClusterSession",
    "ClusterUnsupportedQuery",
    "FaultInjector",
    "Move",
    "Rebalancer",
    "ReplicaDown",
    "ReplicaError",
    "ReplicaSet",
    "ReplicatedPandaDB",
    "ShardMap",
    "ShardedPandaDB",
    "TEMP_BLOB_BASE",
    "close_streams",
    "default_owner_fn",
    "fanout_anchor",
    "hedged_call",
    "id_bound_expr",
    "make_shard",
    "ordered_merge",
    "owner_shard",
    "resilient_stream",
    "stable_id_hash",
]
