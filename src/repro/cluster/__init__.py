"""Sharded cluster subsystem (paper §VII-A): hash-partitioned shards,
replicated index metadata, scatter-gather + routed query serving."""
from repro.cluster.coordinator import (
    ClusterCursor,
    ClusterPreparedStatement,
    ClusterSession,
    ShardedPandaDB,
)
from repro.cluster.partition import (
    TEMP_BLOB_BASE,
    default_owner_fn,
    make_shard,
    owner_shard,
    stable_id_hash,
)
from repro.cluster.scatter import (
    ClusterUnsupportedQuery,
    fanout_anchor,
    id_bound_expr,
    ordered_merge,
)

__all__ = [
    "ClusterCursor",
    "ClusterPreparedStatement",
    "ClusterSession",
    "ClusterUnsupportedQuery",
    "ShardedPandaDB",
    "TEMP_BLOB_BASE",
    "default_owner_fn",
    "fanout_anchor",
    "id_bound_expr",
    "make_shard",
    "ordered_merge",
    "owner_shard",
    "stable_id_hash",
]
