"""Live rebalancing: move node ownership between shards (paper §VII-A).

The coordinator's :class:`~repro.cluster.partition.ShardMap` is a versioned
assignment (base hash + per-node overrides + active-shard list); the
:class:`Rebalancer` changes it safely while the cluster serves:

1. **plan** -- diff a target assignment against current ownership
   (:meth:`Rebalancer.plan_moves`), or derive one from observed skew
   (:meth:`skew_targets`) / a dying shard (:meth:`recovery_targets`);
2. **ship** -- for each move, read the node's property payload + blob
   content + co-located out-edges from a live source replica and apply an
   ``adopt_node`` op on the destination (blob ids are preserved, so index
   identity survives the move); the source disowns the row and drops the
   payload;
3. **re-slice indexes** -- the gathered per-shard IVF pieces merge back
   into the exact build layout (``IVFIndex.merge_pieces``) and re-shard by
   the updated blob ownership (``IVFIndex.shard(assign=)``): no re-train,
   no re-extraction, byte-identical centroids/codes;
4. **publish** -- one shard-map epoch bump per batch (plus one for a
   retirement), which invalidates every cached plan: routing decisions
   bake in the topology.

Dead-shard recovery is a rebalance whose targets spread the dying shard's
rows over the survivors with the SAME rehash rule ``ShardMap.owner`` uses
for base assignments to inactive shards -- so nodes created after the
retirement land consistently with the recovered ones.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.database import PandaDB
from repro.core.vector_index import IVFIndex
from repro.cluster.coordinator import ShardedPandaDB
from repro.cluster.partition import owner_shard
from repro.cluster.replication import ReplicaDown


@dataclasses.dataclass(frozen=True)
class Move:
    node_id: int
    src: int
    dst: int


class Rebalancer:
    """Plans and executes ownership moves on a (replicated) coordinator."""

    def __init__(self, cdb: ShardedPandaDB) -> None:
        self.cdb = cdb

    # -- sources ---------------------------------------------------------------

    def _source_db(self, s: int) -> PandaDB:
        """A live db holding shard ``s``'s payload -- for a replicated
        cluster, any surviving replica (raises :class:`ReplicaDown` when
        the whole set is gone: then there is nothing left to recover)."""
        sets = getattr(self.cdb, "replica_sets", None)
        if sets is not None:
            rs = sets[s]
            return rs.replicas[rs.live()[0]]
        return self.cdb.shards[s]

    def owned_counts(self) -> Dict[int, int]:
        return {s: int(len(self._source_db(s).graph.store.owned_nodes()))
                for s in self.cdb.active}

    # -- planning --------------------------------------------------------------

    def plan_moves(self, target: Dict[int, int]) -> List[Move]:
        """Diff ``{node_id: shard}`` against current ownership; already-
        placed nodes drop out, so re-running a plan is idempotent."""
        return [Move(int(nid), self.cdb.owner_of(int(nid)), int(dst))
                for nid, dst in sorted(target.items())
                if self.cdb.owner_of(int(nid)) != int(dst)]

    def skew_targets(self, threshold: Optional[float] = None
                     ) -> Dict[int, int]:
        """Skew-triggered plan: when the hottest shard owns more than
        ``threshold``x the mean, move half its lead over the coldest shard
        there (highest-id rows move -- they are the youngest, so steady-
        state churn touches the fewest already-cold rows)."""
        cdb = self.cdb
        thr = (threshold if threshold is not None
               else cdb.cfg.cluster.rebalance_skew)
        counts = self.owned_counts()
        if len(counts) < 2:
            return {}
        mean = sum(counts.values()) / len(counts)
        order = sorted(counts)
        hot = max(order, key=lambda s: counts[s])
        cold = min(order, key=lambda s: counts[s])
        if mean <= 0 or counts[hot] < thr * mean:
            return {}
        n_move = (counts[hot] - counts[cold]) // 2
        if n_move <= 0:
            return {}
        nids = self._source_db(hot).graph.store.owned_nodes()
        return {int(n): cold for n in nids[-n_move:]}

    def recovery_targets(self, dead: int) -> Dict[int, int]:
        """Spread a dying shard's rows over the survivors with the exact
        rehash rule ``ShardMap.owner`` applies to inactive base
        assignments."""
        cdb = self.cdb
        survivors = [s for s in cdb.active if s != dead]
        if not survivors:
            raise ValueError(f"no surviving shards besides {dead}")
        nids = self._source_db(dead).graph.store.owned_nodes()
        if len(nids) == 0:
            return {}
        surv = np.asarray(survivors, np.int64)
        dst = surv[owner_shard(nids, len(survivors))]
        return {int(n): int(d) for n, d in zip(nids, dst)}

    # -- execution -------------------------------------------------------------

    def rebalance(self, target: Dict[int, int],
                  retire: Optional[int] = None) -> List[Move]:
        """Execute a target assignment (optionally retiring a shard after
        its rows are out).  Returns the moves performed."""
        cdb = self.cdb
        moves = self.plan_moves(target)
        if not moves and retire is None:
            return moves
        # snapshot index pieces from the CURRENT topology (the to-be-
        # retired shard included) before any payload moves
        sub_keys = list(self._source_db(cdb.active[0]).indexes)
        gathered = {sk: [self._source_db(s).indexes[sk] for s in cdb.active]
                    for sk in sub_keys}
        for mv in moves:
            self._ship(mv)
        cdb.shard_map.reassign({mv.node_id: mv.dst for mv in moves})
        if retire is not None:
            cdb.shard_map.retire(retire)
        # re-slice (not re-train): merge back into the build layout, cut by
        # the updated blob ownership, install on the new active set
        for sk in sub_keys:
            merged = IVFIndex.merge_pieces(gathered[sk])
            assign = np.asarray(
                [cdb._blob_owner[int(b)] for b in merged.ids], np.int64)
            pieces = merged.shard(cdb.n_shards, assign=assign)
            for s in cdb.active:
                cdb._shard_apply(s, "set_index", sk, pieces[s])
            cdb.stats.note_index_rebuild(sk)
        cdb.stats.note_topology_change()
        cdb._count("rebalance_moves", len(moves))
        return moves

    def _ship(self, mv: Move) -> None:
        """Move one node's payload: props + blob content + out-edges to the
        destination (``adopt_node``), disown + drop on the source."""
        cdb = self.cdb
        db = self._source_db(mv.src)
        store = db.graph.store
        nid = mv.node_id
        scalar: Dict[str, Any] = {}
        blob_specs: Dict[str, Tuple[int, bytes, str]] = {}
        for key, col in store.node_props.columns.items():
            if nid >= len(col.present) or not col.present[nid]:
                continue
            if col.kind == "blob":
                bid = int(col.values[nid])
                content = db.graph.blobs.read(bid)
                if content is None:
                    raise KeyError(f"blob {bid} of node {nid} has no "
                                   f"content on shard {mv.src}")
                blob_specs[key] = (bid, content, db.graph.blobs.meta[bid].mime)
            elif col.kind == "string":
                scalar[key] = col.values[nid]
            else:
                scalar[key] = float(col.values[nid])
        edges: List[Tuple[int, str, Dict[str, Any]]] = []
        rels = store.rels
        for eid in rels.out_edges(nid).tolist():
            rprops = {k: (c.values[eid] if c.kind == "string"
                          else float(c.values[eid]))
                      for k, c in store.rel_props.columns.items()
                      if eid < len(c.present) and c.present[eid]}
            edges.append((int(rels.tgt[eid]),
                          store.rel_types.name_of(rels.type_id[eid]),
                          rprops))
        cdb._shard_apply(mv.dst, "adopt_node", nid, scalar, blob_specs, edges)
        for _, (bid, _, _) in blob_specs.items():
            cdb._blob_owner[bid] = mv.dst
        try:
            cdb._shard_apply(mv.src, "set_owner", nid, False)
            for _, (bid, _, _) in blob_specs.items():
                cdb._shard_apply(mv.src, "drop_blob", bid)
        except ReplicaDown:
            pass    # the source set died mid-move: nothing left to disown
