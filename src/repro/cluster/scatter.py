"""Scatter-gather execution legs for the cluster coordinator.

A statement fans out only when every shard can evaluate it over its own
slice with no remote reads -- :func:`fanout_anchor` proves that statically
from the physical plan (the *anchor* is the leaf scan's variable; rows are
owned by the anchor's shard):

* expands must leave the anchor ``out``-ward (edges are co-located with
  their source node, so an owned anchor's out-edges are always local);
* predicates / projections may touch the anchor's properties and
  sub-properties, and any other variable only as a bare id (``__self__``);
* joins and multi-hop chains need distributed joins -- the ROADMAP
  follow-on -- and raise :class:`ClusterUnsupportedQuery` instead of
  silently returning partial rows.

Per-shard streams come from :func:`repro.core.executor.execute_iter_tagged`
(projected rows tagged with anchor ids, per-shard ``LIMIT`` cap), and
:func:`ordered_merge` interleaves them back into the exact single-node row
order: every stream is non-decreasing in anchor id (scans emit ascending
ids; filters/expands preserve order) and ownership is disjoint, so a k-way
merge on the anchor id is a total order.  ``LIMIT`` early exit closes every
shard pipeline (φ cancellation included) as soon as the merged row count
hits the cap.
"""
from __future__ import annotations

import heapq
import sys
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import logical_plan as lp
from repro.core.cypherplus import (
    BoolOp,
    Compare,
    FuncCall,
    Literal,
    MatchQuery,
    Param,
    Prop,
    SubProp,
)


class ClusterUnsupportedQuery(NotImplementedError):
    """The statement needs data that is not shard-local (distributed joins,
    in-expands, remote property reads): see README "Sharded serving"."""


def fanout_anchor(plan: lp.PlanOp) -> str:
    """Validate shard-local evaluability of ``plan``; return the anchor var.

    Raises :class:`ClusterUnsupportedQuery` with the offending construct
    otherwise."""
    node = plan
    if isinstance(node, lp.Limit):
        node = node.child
    proj: Optional[lp.Projection] = None
    if isinstance(node, lp.Projection):
        proj, node = node, node.child
    chain: List[lp.PlanOp] = []
    while True:
        if isinstance(node, (lp.AllNodeScan, lp.NodeByLabelScan)):
            anchor = node.var
            break
        if isinstance(node, (lp.Filter, lp.SemanticFilter, lp.Expand)):
            chain.append(node)
            node = node.child
            continue
        raise ClusterUnsupportedQuery(
            f"{type(node).__name__} needs a distributed join; the cluster "
            f"executes single-anchor pipelines (scan -> filters -> "
            f"out-expands -> project/limit)")
    for op in chain:
        if isinstance(op, lp.Expand):
            if op.src != anchor or op.direction != "out":
                raise ClusterUnsupportedQuery(
                    f"expand ({op.src}){'<-' if op.direction == 'in' else '--'}"
                    f"({op.dst}) is not anchored at {anchor!r} going out: "
                    f"its edges live on another shard")
        else:
            _check_expr(op.predicate, anchor)
    if proj is not None:
        for item in proj.items:
            _check_expr(item.expr, anchor)
    return anchor


def _check_expr(expr: Any, anchor: str) -> None:
    if isinstance(expr, Prop):
        if expr.var != anchor and expr.key != "__self__":
            raise ClusterUnsupportedQuery(
                f"{expr.var}.{expr.key} reads a non-anchor node's property "
                f"(stored on its owner shard); only ids of expanded nodes "
                f"are shard-local")
        return
    if isinstance(expr, SubProp):
        if isinstance(expr.base, Prop):
            if expr.base.var != anchor:
                raise ClusterUnsupportedQuery(
                    f"{expr.base.var}.{expr.base.key}->{expr.sub_key} "
                    f"extracts φ of a non-anchor node's blob (stored on its "
                    f"owner shard)")
            return
        _check_expr(expr.base, anchor)      # query-side createFromSource(...)
        return
    if isinstance(expr, Compare):
        _check_expr(expr.left, anchor)
        _check_expr(expr.right, anchor)
        return
    if isinstance(expr, BoolOp):
        for a in expr.args:
            _check_expr(a, anchor)
        return
    if isinstance(expr, FuncCall):
        for a in expr.args:
            _check_expr(a, anchor)
        return
    # Literal / Param / plain values are shard-local by construction


def _and_conjuncts(expr: Any) -> Iterator[Any]:
    if isinstance(expr, BoolOp) and expr.op == "AND":
        for a in expr.args:
            yield from _and_conjuncts(a)
    elif expr is not None:
        yield expr


def id_bound_expr(q: MatchQuery, anchor: str) -> Optional[Any]:
    """The Literal/Param the anchor is pinned to by an AND-level
    ``anchor = <id>`` conjunct, or None -- the routed-lookup detector."""
    for c in _and_conjuncts(q.where):
        if not (isinstance(c, Compare) and c.op == "="):
            continue
        for a, b in ((c.left, c.right), (c.right, c.left)):
            if (isinstance(a, Prop) and a.var == anchor
                    and a.key == "__self__"
                    and isinstance(b, (Literal, Param))):
                return b
    return None


def resolve_id(expr: Any, params: Dict[str, Any]) -> int:
    if isinstance(expr, Literal):
        return int(expr.value)
    if isinstance(expr, Param):
        if expr.name not in params:
            raise KeyError(f"missing query parameter ${expr.name}")
        return int(params[expr.name])
    return int(expr)


def ordered_merge(streams: List[Iterator[Tuple[np.ndarray, List[Dict]]]],
                  batch_rows: int = 256,
                  limit: Optional[int] = None) -> Iterator[List[Dict]]:
    """K-way merge of tagged per-shard streams into global anchor-id order,
    yielding row batches of ~``batch_rows``.  Pulls a shard's next chunk
    only when its buffer drains (lazy: ``LIMIT`` stops the pulling), and
    closes every stream on exit -- normal exhaustion, early exit, or a
    caller abandoning the cursor all tear the shard pipelines down."""
    bufs: List[Optional[Tuple[np.ndarray, List[Dict], int]]] = \
        [None] * len(streams)

    def refill(s: int) -> bool:
        while True:
            nxt = next(streams[s], None)
            if nxt is None:
                bufs[s] = None
                return False
            ids, rows = nxt
            if rows:
                bufs[s] = (ids, rows, 0)
                return True

    heap: List[Tuple[int, int]] = []
    try:
        for s in range(len(streams)):
            if refill(s):
                heapq.heappush(heap, (int(bufs[s][0][0]), s))
        produced = 0
        out: List[Dict] = []
        while heap:
            _, s = heapq.heappop(heap)
            ids, rows, pos = bufs[s]
            out.append(rows[pos])
            produced += 1
            pos += 1
            if pos < len(rows):
                bufs[s] = (ids, rows, pos)
                heapq.heappush(heap, (int(ids[pos]), s))
            elif refill(s):
                heapq.heappush(heap, (int(bufs[s][0][0]), s))
            if limit is not None and produced >= limit:
                break
            if len(out) >= batch_rows:
                yield out
                out = []
        if out:
            yield out
    finally:
        close_streams(streams)


def close_streams(streams: List[Any]) -> None:
    """Close every per-shard iterator, even when one ``close()`` raises
    (a shard erroring mid-scatter must not leak the other shards' pipeline
    workers / in-flight φ batches).  The first close error is re-raised --
    unless an exception is already propagating (including the GeneratorExit
    of a cursor teardown), which keeps priority."""
    first: Optional[BaseException] = None
    for st in streams:
        close = getattr(st, "close", None)
        if close is None:
            continue
        try:
            close()
        except BaseException as e:  # noqa: BLE001 -- teardown must visit all
            if first is None:
                first = e
    if first is not None and sys.exc_info()[0] is None:
        raise first
