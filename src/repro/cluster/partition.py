"""Hash partitioning for the sharded cluster (paper §VII-A).

Layout rules (what lives where):

* **nodes** -- partitioned by :func:`repro.core.vector_index.stable_id_hash`
  of the node id.  Every shard keeps the full node-id space + labels
  (structure is replicated, so ids stay global and cheap), but properties,
  blobs and scan rows exist only on the owner (``GraphStore.owned``).
* **edges** -- co-located with their *source* node: an out-expand from an
  owned node never leaves the shard.
* **index metadata** -- IVF centroids + PQ codebooks replicated on every
  shard; bucket contents partitioned per shard via ``IVFIndex.shard()``
  with an explicit owner assignment, so a shard's index piece covers
  exactly the blobs its graph slice owns (index pushdown stays shard-local
  and exact).
* **query-side blobs** -- ``createFromSource`` literals materialize per
  shard in a reserved high id range (:data:`TEMP_BLOB_BASE`), disjoint
  from the coordinator's global data-blob sequence, so a temp blob can
  never alias a data blob's φ cache entries.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.pandadb import PandaDBConfig
from repro.core.database import PandaDB
from repro.core.vector_index import owner_shard, stable_id_hash  # noqa: F401

#: auto-allocated (query-side / temp) blob ids start here on every shard;
#: coordinator-assigned data blob ids stay far below
TEMP_BLOB_BASE = 1 << 40


def make_shard(cfg: Optional[PandaDBConfig] = None,
               wal_path: Optional[str] = None) -> PandaDB:
    """One shard replica: a PandaDB whose store tracks ownership and whose
    blob store auto-allocates only from the temp range."""
    db = PandaDB(cfg, wal_path)
    db.graph.store.enable_ownership()
    db.graph.blobs._next_id = TEMP_BLOB_BASE
    return db


def default_owner_fn(n_shards: int):
    """ids -> owning shard, the stable-hash default (injectable in tests to
    force skewed / degenerate partitions)."""
    def fn(ids: np.ndarray) -> np.ndarray:
        return owner_shard(np.asarray(ids), n_shards)
    return fn


class ShardMap:
    """Node -> owning shard as a *versioned, mutable* assignment.

    The base function is the stable-hash default (or an injected policy);
    ``overrides`` records per-node moves (rebalance / dead-shard recovery)
    and ``active`` the shards currently serving.  Base assignments landing
    on a retired shard are re-dealt among the survivors by re-hashing --
    the same rule :meth:`Rebalancer.recovery_targets` uses, so new nodes
    created after a recovery agree with the recovered layout.

    Every topology change bumps ``epoch``; the coordinator folds it into
    the plan-cache key and its statistics epoch so no cached plan or
    shard-positional cost term outlives the assignment it was computed
    for."""

    def __init__(self, n_shards: int,
                 base_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None
                 ) -> None:
        self.n_shards = int(n_shards)
        self.base_fn = base_fn or default_owner_fn(self.n_shards)
        self.overrides: Dict[int, int] = {}
        self.active: List[int] = list(range(self.n_shards))
        self.epoch = 0

    def owner(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        out = np.array(self.base_fn(ids), np.int64, copy=True)
        if len(self.active) != self.n_shards:
            act = np.asarray(self.active, np.int64)
            dead = ~np.isin(out, act)
            if dead.any():
                out[dead] = act[owner_shard(ids[dead], len(act))]
        if self.overrides:
            for i, nid in enumerate(ids.tolist()):
                ov = self.overrides.get(int(nid))
                if ov is not None:
                    out[i] = ov
        return out

    def reassign(self, targets: Dict[int, int]) -> None:
        """Move nodes to explicit owners (one epoch bump per batch)."""
        if not targets:
            return
        for nid, shard in targets.items():
            self.overrides[int(nid)] = int(shard)
        self.epoch += 1

    def retire(self, shard: int) -> None:
        """Take a (dead) shard out of serving; its base-hash slice re-deals
        among the survivors."""
        if shard in self.active:
            if len(self.active) == 1:
                raise ValueError("cannot retire the last active shard")
            self.active.remove(shard)
            self.epoch += 1
