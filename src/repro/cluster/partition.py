"""Hash partitioning for the sharded cluster (paper §VII-A).

Layout rules (what lives where):

* **nodes** -- partitioned by :func:`repro.core.vector_index.stable_id_hash`
  of the node id.  Every shard keeps the full node-id space + labels
  (structure is replicated, so ids stay global and cheap), but properties,
  blobs and scan rows exist only on the owner (``GraphStore.owned``).
* **edges** -- co-located with their *source* node: an out-expand from an
  owned node never leaves the shard.
* **index metadata** -- IVF centroids + PQ codebooks replicated on every
  shard; bucket contents partitioned per shard via ``IVFIndex.shard()``
  with an explicit owner assignment, so a shard's index piece covers
  exactly the blobs its graph slice owns (index pushdown stays shard-local
  and exact).
* **query-side blobs** -- ``createFromSource`` literals materialize per
  shard in a reserved high id range (:data:`TEMP_BLOB_BASE`), disjoint
  from the coordinator's global data-blob sequence, so a temp blob can
  never alias a data blob's φ cache entries.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.pandadb import PandaDBConfig
from repro.core.database import PandaDB
from repro.core.vector_index import owner_shard, stable_id_hash  # noqa: F401

#: auto-allocated (query-side / temp) blob ids start here on every shard;
#: coordinator-assigned data blob ids stay far below
TEMP_BLOB_BASE = 1 << 40


def make_shard(cfg: Optional[PandaDBConfig] = None,
               wal_path: Optional[str] = None) -> PandaDB:
    """One shard replica: a PandaDB whose store tracks ownership and whose
    blob store auto-allocates only from the temp range."""
    db = PandaDB(cfg, wal_path)
    db.graph.store.enable_ownership()
    db.graph.blobs._next_id = TEMP_BLOB_BASE
    return db


def default_owner_fn(n_shards: int):
    """ids -> owning shard, the stable-hash default (injectable in tests to
    force skewed / degenerate partitions)."""
    def fn(ids: np.ndarray) -> np.ndarray:
        return owner_shard(np.asarray(ids), n_shards)
    return fn
