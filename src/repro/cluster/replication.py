"""Self-healing replicated cluster (paper §VII-A high availability).

The paper's cluster keeps R copies of every shard behind the leader's
versioned WAL; here each shard becomes a :class:`ReplicaSet` of R full
:class:`~repro.core.database.PandaDB` nodes:

* **writes** go through the replica set's op log (the leader-WAL path):
  every coordinator write is a named ``(op, args, kwargs)`` tuple recorded
  with an ascending version and applied to every live replica, so a revived
  replica replays exactly the ops it missed (:meth:`ReplicaSet.revive` ==
  the paper's version catch-up for a rejoining node).
* **reads** pick a replica by observed per-replica latency EWMA
  (``StatisticsService.choose_replica``) and are failure-masked three ways:
  retry-with-backoff on transient errors, failover to a sibling replica on
  fail-stop (streams fast-forward past already-merged anchor ids, so the
  merged output is byte-identical to a healthy run), and **hedged reads** --
  if the preferred replica has not answered within a latency-quantile
  deadline (``stats.hedge_deadline``), a second replica races it and the
  first responder wins; the loser is cancelled through the φ-cancelling
  iterator close.

Fault injection (:class:`FaultInjector`: fail-stop, slow-node, error-on-
call, all driven by a seeded RNG) is part of the subsystem so chaos tests
and the failover benchmark exercise exactly the production code paths.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import (CancelledError, FIRST_COMPLETED,
                                ThreadPoolExecutor, wait)
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.configs.pandadb import PandaDBConfig
from repro.core.database import PandaDB
from repro.core.deadline import Deadline
from repro.core.executor import ExecutionContext, execute_iter_tagged
from repro.core.vector_index import scatter_gather_knn
from repro.cluster.coordinator import ShardedPandaDB, _apply_op
from repro.cluster.partition import make_shard
from repro.graphstore.wal import WriteAheadLog


class ReplicaDown(RuntimeError):
    """The replica is fail-stopped (or a whole shard has no live replica)."""


class ReplicaError(RuntimeError):
    """A transient per-call fault -- retryable on the same replica."""


class FaultInjector:
    """Deterministic fault injection, consulted on every replica access.

    All randomness (probabilistic slow-downs) comes from one seeded
    generator, so chaos tests and the failover benchmark are exactly
    reproducible run-to-run."""

    def __init__(self, seed: int = 0) -> None:
        self.rng = np.random.default_rng(seed)
        self._down: Set[Tuple[int, int]] = set()
        self._slow: Dict[Tuple[int, int], Tuple[float, float]] = {}
        self._errors: Dict[Tuple[int, int], int] = {}
        self.injected: Dict[str, int] = {"fail_stops": 0, "slow_sleeps": 0,
                                         "errors": 0}
        self._lock = threading.Lock()

    def fail_stop(self, shard: int, replica: int) -> None:
        """Kill (shard, replica): every subsequent access raises
        :class:`ReplicaDown` until :meth:`heal`."""
        with self._lock:
            self._down.add((shard, replica))
            self.injected["fail_stops"] += 1

    def slow(self, shard: int, replica: int, delay_s: float,
             prob: float = 1.0) -> None:
        """Each access sleeps ``delay_s`` with probability ``prob``."""
        with self._lock:
            self._slow[(shard, replica)] = (float(delay_s), float(prob))

    def error_on_call(self, shard: int, replica: int, times: int = 1) -> None:
        """The next ``times`` accesses raise :class:`ReplicaError`."""
        with self._lock:
            self._errors[(shard, replica)] = \
                self._errors.get((shard, replica), 0) + int(times)

    def heal(self, shard: int, replica: int) -> None:
        with self._lock:
            self._down.discard((shard, replica))
            self._slow.pop((shard, replica), None)
            self._errors.pop((shard, replica), None)

    def is_down(self, shard: int, replica: int) -> bool:
        with self._lock:
            return (shard, replica) in self._down

    def check(self, shard: int, replica: int) -> None:
        """Read-path gate: raise / delay according to the injected faults
        (the sleep happens outside the lock so slow replicas do not stall
        fault bookkeeping for the healthy ones)."""
        key = (shard, replica)
        delay = 0.0
        with self._lock:
            if key in self._down:
                raise ReplicaDown(f"shard {shard} replica {replica} is down")
            n = self._errors.get(key, 0)
            if n > 0:
                self._errors[key] = n - 1
                self.injected["errors"] += 1
                raise ReplicaError(
                    f"injected transient error on shard {shard} "
                    f"replica {replica}")
            sl = self._slow.get(key)
            if sl is not None:
                d, p = sl
                if p >= 1.0 or float(self.rng.random()) < p:
                    delay = d
                    self.injected["slow_sleeps"] += 1
        if delay > 0.0:
            time.sleep(delay)


class CircuitBreaker:
    """Per-replica failure gate: closed -> open -> half-open -> closed.

    ``record_failure`` counts *consecutive* failures (a success resets);
    hitting the threshold -- or any failure while half-open -- trips the
    breaker OPEN for ``reset_s``, during which :meth:`allow` refuses the
    replica so retries stop hammering a node that keeps failing.  After the
    cool-down exactly ONE caller is admitted as the half-open probe; its
    success closes the breaker, its failure re-opens it.  Slow calls
    (latency above ``slow_call_s``, when enabled) count as failures, so a
    consistently lagging replica is quarantined like a flapping one.

    ``opens``/``probes``/``closes`` are cumulative transition counters --
    the chaos suite asserts recovery shapes on these instead of timing."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failures: int = 2, reset_s: float = 0.25,
                 slow_call_s: float = 0.0) -> None:
        self.failure_threshold = max(1, int(failures))
        self.reset_s = float(reset_s)
        self.slow_call_s = float(slow_call_s)
        self.state = self.CLOSED
        self.opens = 0
        self.probes = 0
        self.closes = 0
        self._consecutive = 0
        self._probing = False
        self._probe_at = 0.0
        self._open_until = 0.0
        self._lock = threading.Lock()

    def allow(self) -> bool:
        """May this replica serve a read right now?  Transitions OPEN ->
        HALF_OPEN once the cool-down has passed; in HALF_OPEN admits only
        one probe at a time (an admitted-but-unresolved probe expires after
        ``reset_s``, so a probe the replica picker never actually routed to
        cannot wedge the breaker half-open forever)."""
        with self._lock:
            if self.state == self.CLOSED:
                return True
            now = time.perf_counter()
            if self.state == self.OPEN:
                if now < self._open_until:
                    return False
                self.state = self.HALF_OPEN
                self._probing = False
            if self._probing and now - self._probe_at <= self.reset_s:
                return False
            self._probing = True
            self._probe_at = now
            self.probes += 1
            return True

    def record_success(self, latency_s: float = 0.0) -> None:
        with self._lock:
            if 0.0 < self.slow_call_s < latency_s:
                self._failure_locked()
                return
            if self.state != self.CLOSED:
                self.closes += 1
            self.state = self.CLOSED
            self._consecutive = 0
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self._failure_locked()

    def trip(self) -> None:
        """Immediate open (an observed fail-stop needs no vote count)."""
        with self._lock:
            self._trip_locked()

    def reset_half_open(self) -> None:
        """Post-``revive()``: skip the cool-down so the next read is the
        probe that can bring the replica back into rotation."""
        with self._lock:
            if self.state != self.CLOSED:
                self.state = self.HALF_OPEN
                self._probing = False

    def _failure_locked(self) -> None:
        self._consecutive += 1
        if (self.state == self.HALF_OPEN
                or self._consecutive >= self.failure_threshold):
            self._trip_locked()

    def _trip_locked(self) -> None:
        if self.state != self.OPEN:
            self.opens += 1
        self.state = self.OPEN
        self._probing = False
        self._consecutive = max(self._consecutive, self.failure_threshold)
        self._open_until = time.perf_counter() + self.reset_s


class ReplicaSet:
    """R copies of one shard behind a versioned op log (§VII-A).

    Writes append to the log first, then apply to every live replica;
    ``versions[r]`` tracks how far replica ``r`` has replayed, so
    :meth:`revive` is exactly the paper's catch-up: replay every logged op
    past the local version, then rejoin."""

    def __init__(self, shard_id: int, replicas: List[PandaDB],
                 faults: FaultInjector,
                 on_dead: Optional[Callable[[int, int], None]] = None,
                 breaker_failures: int = 2, breaker_reset_s: float = 0.25,
                 breaker_slow_call_s: float = 0.0) -> None:
        self.shard_id = shard_id
        self.replicas = replicas
        self.faults = faults
        self.alive = [True] * len(replicas)
        self.versions = [0] * len(replicas)
        self.oplog = WriteAheadLog(None)
        self.breakers = [CircuitBreaker(breaker_failures, breaker_reset_s,
                                        breaker_slow_call_s)
                         for _ in replicas]
        #: notified once per alive->dead transition the set itself observes
        #: (the coordinator counts these as failovers)
        self.on_dead = on_dead

    def _fold_down(self, r: int) -> None:
        self.alive[r] = False
        self.breakers[r].trip()
        if self.on_dead is not None:
            self.on_dead(self.shard_id, r)

    def note_success(self, r: int, latency_s: float = 0.0) -> None:
        self.breakers[r].record_success(latency_s)

    def note_failure(self, r: int) -> None:
        self.breakers[r].record_failure()

    def selectable(self) -> List[int]:
        """Live replicas whose breaker admits a call right now.  When every
        live breaker refuses (all open inside their cool-down) fall back to
        plain :meth:`live` -- serving from a suspect replica beats serving
        nothing."""
        live = self.live()
        out = [r for r in live if self.breakers[r].allow()]
        return out or live

    def live(self) -> List[int]:
        """Live replica indices; folds fail-stops observed since the last
        call into ``alive``.  Raises :class:`ReplicaDown` when the whole
        set is gone (recovery is then the rebalancer's job)."""
        out: List[int] = []
        for r in range(len(self.replicas)):
            if self.alive[r] and self.faults.is_down(self.shard_id, r):
                self._fold_down(r)
            if self.alive[r]:
                out.append(r)
        if not out:
            raise ReplicaDown(f"shard {self.shard_id}: no live replicas")
        return out

    def mark_dead(self, r: int) -> None:
        if self.alive[r]:
            self._fold_down(r)

    def apply(self, op: str, args: tuple, kw: Dict[str, Any]) -> Any:
        """Log the op, then apply it to every live replica (write path:
        only fail-stop is consulted -- a slow replica still applies every
        write, so replicas never diverge)."""
        ver = self.oplog.append((op, args, kw))
        result: Any = None
        applied = False
        for r, db in enumerate(self.replicas):
            if not self.alive[r]:
                continue
            if self.faults.is_down(self.shard_id, r):
                self._fold_down(r)
                continue
            result = _apply_op(db, op, args, kw)
            self.versions[r] = ver
            applied = True
        if not applied:
            raise ReplicaDown(
                f"shard {self.shard_id}: write {op!r} found no live replica")
        return result

    def revive(self, r: int) -> int:
        """Heal the fault, replay the missed ops in log order, rejoin.
        Returns the number of ops replayed."""
        self.faults.heal(self.shard_id, r)
        db = self.replicas[r]
        before = self.versions[r]
        self.versions[r] = self.oplog.catch_up(
            before, lambda e: _apply_op(db, e[0], e[1], e[2]))
        self.alive[r] = True
        # skip the breaker cool-down: the next read against this replica is
        # the half-open probe that can fold it back into rotation
        self.breakers[r].reset_half_open()
        return self.versions[r] - before


# -- hedged + failover read machinery -----------------------------------------

_DONE = object()

#: what a loser's φ-cancelling close is ALLOWED to raise: the stream resuming
#: into an injected fault (ReplicaDown/ReplicaError), generator shutdown
#: protocol noise (GeneratorExit escaping a nested close, RuntimeError from
#: "generator ignored GeneratorExit" / "already executing").  Anything else
#: is a real teardown bug -- counted, not swallowed silently.
_EXPECTED_TEARDOWN = (ReplicaDown, ReplicaError, GeneratorExit, RuntimeError,
                      ValueError)


def _close_quiet(it: Any, cdb: Optional["ReplicatedPandaDB"] = None) -> None:
    close = getattr(it, "close", None)
    if close is None:
        return
    try:
        close()
    except _EXPECTED_TEARDOWN:
        pass                        # loser teardown is best-effort
    except Exception:  # noqa: BLE001 -- surfaced via cluster counters
        if cdb is None:
            raise
        cdb._count("teardown_errors")


def _loser_reaper(cdb: "ReplicatedPandaDB", shard: int, r: int,
                  on_loser: Optional[Callable[[Any], None]],
                  trace=None):
    def reap(fu) -> None:
        try:
            exc = fu.exception()
        except CancelledError:
            return                  # close() cancelled it before it ran
        # reapers run as done-callbacks, possibly after the query's trace
        # closed -- a late event must not break the trace's nesting
        if trace is not None and not trace.root.closed:
            trace.event("hedge.loser_reap", parent=trace.root,
                        shard=shard, replica=r,
                        error=type(exc).__name__ if exc is not None else None)
        if exc is not None:
            if isinstance(exc, ReplicaDown):
                cdb.replica_sets[shard].mark_dead(r)
            elif not isinstance(exc, ReplicaError):
                # a loser failing with anything but an injected fault is a
                # teardown bug; fold it into the chaos-test counters
                cdb._count("teardown_errors")
            return
        if on_loser is None:
            return
        try:
            on_loser(fu.result())
        except _EXPECTED_TEARDOWN:
            pass
        except Exception:  # noqa: BLE001 -- done-callbacks must not raise
            cdb._count("teardown_errors")
    return reap


def hedged_call(cdb: "ReplicatedPandaDB", shard: int, live: List[int],
                call: Callable[[int], Any],
                on_loser: Optional[Callable[[Any], None]] = None,
                deadline: Optional[Deadline] = None,
                trace=None) -> Tuple[Any, int]:
    """Run ``call(replica)`` on the latency-preferred replica; if it has
    not answered within the shard's hedge deadline, race the next-best
    replica and take the first *success* (ties in the same wait batch
    prefer the primary, so an un-faulted cluster behaves exactly
    un-hedged).  Returns ``(result, winning replica)``.

    With a ``deadline``, every wait is clamped to the remaining budget and
    an expired budget abandons the race (legs are reaped, never orphaned)
    instead of blocking on a replica that will not answer in time.  Each
    leg's failure is charged to that replica's circuit breaker.

    Losers are not abandoned: a done-callback closes their result through
    ``on_loser`` (for streams: the φ-cancelling iterator close) and folds a
    late :class:`ReplicaDown` into the replica set."""
    rs = cdb.replica_sets[shard]
    primary = cdb.stats.choose_replica(shard, live)
    if trace is not None:
        trace.event("replica.pick", shard=shard, replica=primary,
                    breakers=",".join(b.state for b in rs.breakers))
    pool = cdb._hedge_pool
    if pool is None or len(live) < 2:
        try:
            out = call(primary)
        except (ReplicaDown, ReplicaError):
            rs.note_failure(primary)
            raise
        return out, primary
    futs = {cdb._track_hedge(pool.submit(call, primary)): primary}
    hedge_to = cdb.stats.hedge_deadline(shard)
    if deadline is not None:
        hedge_to = deadline.clamp(hedge_to)
    done, _ = wait(list(futs), timeout=hedge_to)
    if not done:
        backup = min(
            (r for r in live if r != primary),
            key=lambda r: (cdb.stats.replica_read_latency(shard, r), r))
        cdb._count("hedges_fired")
        if trace is not None:
            trace.event("hedge.fire", shard=shard, primary=primary,
                        backup=backup)
        futs[cdb._track_hedge(pool.submit(call, backup))] = backup
    winner = None
    last_exc: Optional[BaseException] = None
    pending = set(futs)
    while pending and winner is None:
        if deadline is None:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
        else:
            done, pending = wait(pending, return_when=FIRST_COMPLETED,
                                 timeout=max(0.0, deadline.remaining()))
            if not done and deadline.expired():
                # budget gone: reap every leg still racing and fail fast
                for fu, r in futs.items():
                    fu.add_done_callback(
                        _loser_reaper(cdb, shard, r, on_loser, trace=trace))
                deadline.check("hedged read")
        for fu in sorted(done, key=lambda f: futs[f] != primary):
            exc = fu.exception()
            if exc is None:
                winner = fu
                break
            last_exc = exc
            if isinstance(exc, (ReplicaDown, ReplicaError)):
                rs.note_failure(futs[fu])
            if isinstance(exc, ReplicaDown):
                rs.mark_dead(futs[fu])
    if winner is None:
        assert last_exc is not None
        raise last_exc
    if futs[winner] != primary:
        cdb._count("hedges_won")
        if trace is not None:
            trace.event("hedge.win", shard=shard, replica=futs[winner])
    for fu, r in futs.items():
        if fu is not winner:
            fu.add_done_callback(_loser_reaper(cdb, shard, r, on_loser,
                                               trace=trace))
    return winner.result(), futs[winner]


def _pull_first(cdb: "ReplicatedPandaDB", shard: int, r: int,
                open_on: Callable[[int], Any]) -> Tuple[Any, Any, float]:
    """Open replica ``r``'s stream and pull its first batch (streams are
    lazy, so hedging must cover the first real pull, not just iterator
    construction).  Returns (iterator, first batch or _DONE, seconds)."""
    t0 = time.perf_counter()
    cdb.faults.check(shard, r)
    it = open_on(r)
    try:
        first = next(it, _DONE)
    except BaseException:
        _close_quiet(it, cdb)
        raise
    return it, first, time.perf_counter() - t0


def _open_stream(cdb: "ReplicatedPandaDB", shard: int,
                 open_on: Callable[[int], Any],
                 deadline: Optional[Deadline] = None,
                 trace=None) -> Tuple[Any, Any, int]:
    """Open a stream on *some* live replica: hedged first pull, transient
    errors retried with linear backoff (clamped to any remaining deadline
    budget), fail-stops failed over until the replica set itself is
    exhausted.  Candidate replicas are breaker-filtered, so a replica that
    just burned its failure budget is skipped instead of re-tried."""
    rs = cdb.replica_sets[shard]
    attempts = 0
    while True:
        if deadline is not None:
            deadline.check("stream open")
        live = rs.selectable()
        try:
            (it, first, dt), r = hedged_call(
                cdb, shard, live,
                lambda rr: _pull_first(cdb, shard, rr, open_on),
                on_loser=lambda res: _close_quiet(res[0], cdb),
                deadline=deadline, trace=trace)
        except ReplicaDown:
            continue        # rs.live() shrinks; raises once the set is gone
        except ReplicaError:
            attempts += 1
            cdb._count("retries")
            if trace is not None:
                trace.event("retry", shard=shard, attempt=attempts,
                            where="stream_open")
            if attempts > cdb.cfg.cluster.read_retries:
                raise
            backoff = cdb.cfg.cluster.retry_backoff_s * attempts
            if deadline is not None:
                deadline.check("stream open retry")
                backoff = deadline.clamp(backoff)
            time.sleep(backoff)
            continue
        rs.note_success(r, dt)
        cdb.stats.record_replica_read(shard, r, dt)
        cdb._count_replica_read(shard, r)
        return it, first, r


def resilient_stream(cdb: "ReplicatedPandaDB", shard: int,
                     open_on: Callable[[int], Any],
                     deadline: Optional[Deadline] = None,
                     trace=None):
    """A tagged per-shard stream that survives replica failure mid-pull.

    Every batch pull is fault-gated and latency-recorded; on fail-stop the
    stream fails over: a fresh iterator opens on a sibling replica and
    fast-forwards past the anchor ids already yielded (streams are
    non-decreasing in anchor id and identical across replicas, so the
    filter ``ids > last_id`` resumes exactly where the dead replica
    stopped -- the merged output is byte-identical to a healthy run)."""
    rs = cdb.replica_sets[shard]
    last_id = -1
    it = None
    r = -1
    try:
        while True:
            if it is None:
                if trace is not None and r >= 0:
                    # a replica died mid-stream: the reopen-on-a-sibling +
                    # fast-forward is the failover the chaos suite asserts on
                    with trace.span("failover", shard=shard,
                                    from_replica=r) as sp:
                        it, nxt, r = _open_stream(cdb, shard, open_on,
                                                  deadline, trace=trace)
                        sp.set(to_replica=r)
                else:
                    it, nxt, r = _open_stream(cdb, shard, open_on, deadline,
                                              trace=trace)
            else:
                attempts = 0
                while True:
                    t0 = time.perf_counter()
                    try:
                        cdb.faults.check(shard, r)
                        nxt = next(it, _DONE)
                    except ReplicaDown:
                        rs.note_failure(r)
                        rs.mark_dead(r)
                        _close_quiet(it, cdb)
                        it = None
                        break
                    except ReplicaError:
                        rs.note_failure(r)
                        attempts += 1
                        cdb._count("retries")
                        if trace is not None:
                            trace.event("retry", shard=shard, replica=r,
                                        attempt=attempts, where="stream_pull")
                        if attempts > cdb.cfg.cluster.read_retries:
                            rs.mark_dead(r)
                            _close_quiet(it, cdb)
                            it = None
                            break
                        backoff = cdb.cfg.cluster.retry_backoff_s * attempts
                        if deadline is not None:
                            deadline.check("stream pull retry")
                            backoff = deadline.clamp(backoff)
                        time.sleep(backoff)
                        continue
                    dt = time.perf_counter() - t0
                    rs.note_success(r, dt)
                    cdb.stats.record_replica_read(shard, r, dt)
                    break
                if it is None:
                    continue            # reopen on a sibling + fast-forward
            if nxt is _DONE:
                return
            ids, rows = nxt
            if last_id >= 0 and len(ids) and int(ids[0]) <= last_id:
                keep = ids > last_id
                rows = [row for row, kk in zip(rows, keep) if kk]
                ids = ids[keep]
            if len(ids):
                last_id = int(ids[-1])
                yield ids, rows
    finally:
        if it is not None:
            it.close()


class _ResilientIndex:
    """Duck-typed shard view for :func:`scatter_gather_knn`: ``search_many``
    hedges across the shard's live replicas with retry + failover, so one
    merge schedule serves healthy and degraded clusters identically
    (replicas hold the same piece, so any winner returns the same rows)."""

    def __init__(self, cdb: "ReplicatedPandaDB", shard: int, sub_key: str,
                 deadline: Optional[Deadline] = None, trace=None) -> None:
        self.cdb = cdb
        self.shard = shard
        self.sub_key = sub_key
        self.deadline = deadline
        self.trace = trace
        self.scan_rows = 0
        rs = cdb.replica_sets[shard]
        piece = rs.replicas[rs.live()[0]].indexes[sub_key]
        self.n_total = piece.n_total
        self.centroids = piece.centroids
        self.cfg = piece.cfg

    def _search_on(self, r: int, queries, k, nprobe, mode, rerank,
                   rerank_mult=None):
        cdb, s = self.cdb, self.shard
        t0 = time.perf_counter()
        cdb.faults.check(s, r)
        db = cdb.replica_sets[s].replicas[r]
        piece = db.indexes[self.sub_key]
        rows0 = piece.scan_rows
        v, i = piece.search_many(queries, k, nprobe, stats=db.stats,
                                 mode=mode, rerank=rerank,
                                 rerank_mult=rerank_mult)
        cdb.stats.record_replica_read(s, r, time.perf_counter() - t0)
        cdb._count_replica_read(s, r)
        return v, i, piece.scan_rows - rows0

    def search_many(self, queries, k, nprobe=None, stats=None, mode="auto",
                    rerank=True, rerank_mult=None):
        cdb, s = self.cdb, self.shard
        rs = cdb.replica_sets[s]
        deadline = self.deadline
        attempts = 0
        while True:
            if deadline is not None:
                deadline.check("knn search")
            live = rs.selectable()
            try:
                (v, i, rows), r = hedged_call(
                    cdb, s, live,
                    lambda rr: self._search_on(rr, queries, k, nprobe, mode,
                                               rerank, rerank_mult),
                    deadline=deadline, trace=self.trace)
            except ReplicaDown:
                continue
            except ReplicaError:
                attempts += 1
                cdb._count("retries")
                if self.trace is not None:
                    self.trace.event("retry", shard=s, attempt=attempts,
                                     where="knn")
                if attempts > cdb.cfg.cluster.read_retries:
                    raise
                backoff = cdb.cfg.cluster.retry_backoff_s * attempts
                if deadline is not None:
                    deadline.check("knn retry")
                    backoff = deadline.clamp(backoff)
                time.sleep(backoff)
                continue
            rs.note_success(r)
            self.scan_rows += rows
            return v, i


class ReplicatedPandaDB(ShardedPandaDB):
    """:class:`ShardedPandaDB` with R replicas per shard.

    Same coordinator surface (sessions, kNN, CREATE, explain); the replica
    hooks route reads through latency-based replica choice + hedging +
    failover and writes through the per-shard op log."""

    def __init__(self, n_shards: Optional[int] = None,
                 cfg: Optional[PandaDBConfig] = None,
                 owner_fn=None, replication: Optional[int] = None,
                 faults: Optional[FaultInjector] = None) -> None:
        cfg = cfg or PandaDBConfig()
        self.replication = int(replication or cfg.cluster.replication)
        if self.replication < 1:
            raise ValueError(
                f"replication must be >= 1, got {self.replication}")
        self.faults = faults or FaultInjector(seed=0)
        self.replica_sets: List[ReplicaSet] = []
        self._hedge_pool: Optional[ThreadPoolExecutor] = None
        self._hedge_inflight: Set[Any] = set()
        self._hedge_lock = threading.Lock()
        super().__init__(n_shards, cfg, owner_fn)
        for rs in self.replica_sets:
            for db in rs.replicas:
                db.plan_cache = self.plan_cache
        if self.cfg.cluster.hedge_reads and self.replication > 1:
            # dedicated pool: hedges are issued FROM scatter-pool workers,
            # so sharing that pool could deadlock at full fan-out
            self._hedge_pool = ThreadPoolExecutor(
                max_workers=2 * self.n_shards, thread_name_prefix="hedge")

    def _make_shards(self) -> List[PandaDB]:
        # every alive->dead transition a replica set observes is a failover
        # (counters exist by first use: live() only runs post-__init__)
        on_dead = lambda s, r: self._count("failovers")  # noqa: E731
        cl = self.cfg.cluster
        self.replica_sets = [
            ReplicaSet(s, [make_shard(self.cfg)
                           for _ in range(self.replication)], self.faults,
                       on_dead=on_dead,
                       breaker_failures=cl.breaker_failures,
                       breaker_reset_s=cl.breaker_reset_s,
                       breaker_slow_call_s=cl.breaker_slow_call_s)
            for s in range(self.n_shards)]
        return [rs.replicas[0] for rs in self.replica_sets]

    def _track_hedge(self, fu):
        """Register an in-flight hedge leg so :meth:`close` can drain the
        legs still running on pool threads (a discard-on-done callback
        keeps the set O(open legs))."""
        with self._hedge_lock:
            self._hedge_inflight.add(fu)

        def _untrack(f) -> None:
            with self._hedge_lock:
                self._hedge_inflight.discard(f)

        fu.add_done_callback(_untrack)
        return fu

    def close(self) -> None:
        """Idempotent teardown.  ``cancel_futures=True`` drops every hedge
        leg still queued (they would otherwise run against retiring
        replicas after close returns); legs already RUNNING on a pool
        thread cannot be cancelled, so close drains them with a bounded
        wait instead of abandoning them mid-read -- a hedge landing after
        close neither deadlocks nor touches a retired replica."""
        super().close()
        pool, self._hedge_pool = self._hedge_pool, None
        if pool is None:
            return
        pool.shutdown(wait=False, cancel_futures=True)
        with self._hedge_lock:
            running = [fu for fu in self._hedge_inflight if not fu.done()]
        if running:
            wait(running, timeout=self.cfg.cluster.close_drain_s)

    def revive(self, shard: int, replica: int) -> int:
        """Heal + catch up one replica from the shard's op log (§VII-A
        rejoin).  Returns the number of ops replayed."""
        return self.replica_sets[shard].revive(replica)

    # -- replica hooks ---------------------------------------------------------

    def read_db(self, s: int) -> PandaDB:
        rs = self.replica_sets[s]
        r = self.stats.choose_replica(s, rs.selectable())
        self._count_replica_read(s, r)
        return rs.replicas[r]

    def _shard_apply(self, s: int, op: str, *args: Any, **kw: Any) -> Any:
        return self.replica_sets[s].apply(op, args, kw)

    def _shard_stream(self, plan, s, params, anchor, batch_rows, limit,
                      prefetch_depth, deadline=None, trace=None,
                      profile=None):
        rs = self.replica_sets[s]
        if profile is not None:
            profile.note_shard(s)

        def open_on(r: int):
            ctx = ExecutionContext(rs.replicas[r], params,
                                   prefetch_depth=prefetch_depth,
                                   deadline=deadline,
                                   trace=trace, profile=profile)
            return execute_iter_tagged(plan, ctx, anchor, batch_rows,
                                       limit=limit)

        return resilient_stream(self, s, open_on, deadline=deadline,
                                trace=trace)

    def knn(self, sub_key: str, queries, k: int, nprobe: Optional[int] = None,
            mode: str = "auto", rerank: bool = True,
            deadline_ms: Optional[float] = None, trace=None):
        deadline = Deadline.resolve(deadline_ms)
        own_trace = trace is None and self.tracer.enabled
        if own_trace:
            trace = self.tracer.begin("knn", sub_key=sub_key, k=k)
        views = [_ResilientIndex(self, s, sub_key, deadline=deadline,
                                 trace=trace)
                 for s in self.active]
        try:
            out = scatter_gather_knn(
                views, queries, k, nprobe=nprobe,
                mode=mode, rerank=rerank, stats=None,
                record=self.stats.record_shard_scan,
                pool=self._pool,
                split_rerank_budget=self.cfg.cluster.split_rerank_budget,
                deadline=deadline, trace=trace)
        finally:
            if own_trace and trace is not None:
                trace.finish()
        if deadline is not None and "partial_topk" in deadline.degradations:
            self._count("degraded")
        return out

    def cluster_counters(self) -> Dict[str, int]:
        out = dict(super().cluster_counters())
        opens = probes = closes = 0
        for rs in self.replica_sets:
            for b in rs.breakers:
                opens += b.opens
                probes += b.probes
                closes += b.closes
        out["breaker_opens"] = opens
        out["breaker_probes"] = probes
        out["breaker_closes"] = closes
        # mirror the breaker transition totals into the registry so the
        # Prometheus dump / global_snapshot see them without a second path
        self.metrics.gauge("breaker_opens").set(opens)
        self.metrics.gauge("breaker_probes").set(probes)
        self.metrics.gauge("breaker_closes").set(closes)
        return out

    def explain(self, text: str) -> Dict[str, Any]:
        out = super().explain(text)
        out["replication"] = self.replication
        out["alive"] = {s: list(self.replica_sets[s].alive)
                        for s in range(self.n_shards)}
        out["breakers"] = {s: [b.state for b in self.replica_sets[s].breakers]
                           for s in range(self.n_shards)}
        out["hedge_deadline_s"] = {s: self.stats.hedge_deadline(s)
                                   for s in self.active}
        return out
