import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration runner (§Perf): lower + compile a cell VARIANT, print the
roofline terms + top contributors, persist to results/perf/.

  PYTHONPATH=src python -m repro.launch.perf --arch llama3-8b --shape train_4k \
      --variant baseline
  PYTHONPATH=src python -m repro.launch.perf --list

Variants are (rule_overrides, cfg_overrides) pairs registered per cell below;
each corresponds to one hypothesis in EXPERIMENTS.md §Perf.
"""
import argparse
import json
import time
from pathlib import Path

import jax

from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import build_step

# ---------------------------------------------------------------------------
# variant registry: cell -> name -> dict(rules=..., cfg=...)
# ---------------------------------------------------------------------------

VARIANTS = {
    ("llama3-8b", "train_4k"): {
        "baseline": {},
        # H1: pure FSDP -- fold the model axis into data-parallel batch,
        # shard params over BOTH axes; kills the Megatron activation
        # all-reduces entirely.
        "fsdp_only": {"rules": {
            "batch": ("data", "model"),
            "heads": None, "mlp": None, "vocab": None, "kv_heads": None,
            "p_heads": "model", "p_mlp": "model", "p_vocab": "model",
            "p_kv_heads": None,      # kv=8 < model axis: data-shard via d only
        }},
        # H2: fewer microbatches (fewer FSDP regathers, more activation mem)
        "accum2": {"cfg": {"grad_accum": 2}},
        # H3: larger attention KV blocks (fewer score-chain materializations)
        "blk4096": {"cfg": {"attn_block_kv": 4096}},
        # H4: fused rmsnorm (no fp32 materialization)
        "fused_norm": {"cfg": {"fused_norm": True}},
        # H5: bf16 softmax weights
        "bf16_probs": {"cfg": {"bf16_probs": True}},
        # H6b: fsdp with accum=1 (microbatch must cover the full mesh)
        "fsdp_accum1": {"rules": {
            "batch": ("data", "model"),
            "heads": None, "mlp": None, "vocab": None, "kv_heads": None,
            "p_heads": "model", "p_mlp": "model", "p_vocab": "model",
            "p_kv_heads": None},
            "cfg": {"grad_accum": 1, "attn_block_kv": 4096,
                    "fused_norm": True}},
        # H7: fsdp_accum1 + bf16 softmax weights (single-block: no rescale)
        "combo": {"rules": {
            "batch": ("data", "model"),
            "heads": None, "mlp": None, "vocab": None, "kv_heads": None,
            "p_heads": "model", "p_mlp": "model", "p_vocab": "model",
            "p_kv_heads": None},
            "cfg": {"grad_accum": 1, "attn_block_kv": 4096,
                    "fused_norm": True, "bf16_probs": True}},
    },
    ("deepseek-v2-236b", "train_4k"): {
        "baseline": {},
        "accum8": {"cfg": {"grad_accum": 8}},
        "accum32": {"cfg": {"grad_accum": 32}},
        # EP-heavy: keep experts on model axis but stop sharding attn heads
        # (MLA latent is small; replicating attention kills its all-reduces)
        "ep_only_attn_replicated": {"rules": {
            "heads": None, "p_heads": None, "mlp": None, "vocab": None}},
        # capacity factor reduction (less dispatch padding)
        "cap1": {"cfg": {"capacity_factor": 1.0}},
        "fused_norm": {"cfg": {"fused_norm": True}},
        "blk4096": {"cfg": {"attn_block_kv": 4096}},
        "combo": {"cfg": {"fused_norm": True, "grad_accum": 8,
                          "attn_block_kv": 4096}},
        # H8: vmapped combine scatter (batch-local; code change) + winners
        "vmap_combine": {"cfg": {"attn_block_kv": 4096,
                                 "capacity_factor": 1.0}},
    },
    ("equiformer-v2", "ogb_products"): {
        # NOTE: "baseline" now includes the SH-row fast-logits pass-1 (code
        # change); the pre-change baseline is the dry-run JSON.  Name it
        # explicitly for the §Perf log.
        "baseline": {},
        "fast_logits": {},
        "fast_logits_remat": {},
        "rowln_stopgrad": {},
        "custom_vjp": {},
        "custom_vjp_rows": {},
        "pin_channel": {},
        "custom_vjp_bf16": {"cfg": {"dtype": "bfloat16"}},
        # bf16 irrep features end-to-end + remat
        "bf16_remat": {"cfg": {"dtype": "bfloat16"}},
    },
    ("autoint", "retrieval_cand"): {
        "baseline": {},
        # score in bf16 (candidates are the dominant read)
        "bf16_cands": {"flags": {"bf16_cands": True}},
    },
}


def run_variant(arch: str, shape: str, variant: str, multi_pod: bool = False,
                out_dir: str = "results/perf") -> dict:
    spec = VARIANTS.get((arch, shape), {"baseline": {}})
    v = spec[variant]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = build_step(arch, shape, mesh, rule_overrides=v.get("rules"),
                        cfg_overrides=v.get("cfg"))
    with jax.set_mesh(mesh):
        compiled = jax.jit(
            bundle.fn, in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        ).lower(*bundle.abstract_args).compile()
    an = analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    d = an.as_dict()
    res = {
        "arch": arch, "shape": shape, "variant": variant,
        "compile_s": round(time.time() - t0, 1),
        "compute_s": an.flops / HW["peak_flops_bf16"],
        "memory_s": an.bytes_accessed / HW["hbm_bw"],
        "collective_s": an.collective_bytes / HW["ici_bw"],
        "temp_gb": (mem.temp_size_in_bytes / 1e9) if mem else None,
        "top_bytes": d["top_bytes"],
        "top_flops": d["top_flops"][:6],
        "top_collectives": d["top_collectives"],
        "trip_counts": d["trip_counts"],
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{arch}__{shape}__{variant}.json").write_text(
        json.dumps(res, indent=1))
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for (a, s), vs in VARIANTS.items():
            print(f"{a} x {s}: {sorted(vs)}")
        return
    res = run_variant(args.arch, args.shape, args.variant)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("top_bytes", "top_flops",
                                   "top_collectives")}, indent=1))
    print("--- top bytes ---")
    for k, v in res["top_bytes"]:
        print(f"  {v / 1e9:10.1f} GB  {k}")
    print("--- top collectives ---")
    for k, v in res["top_collectives"]:
        print(f"  {v / 1e9:10.1f} GB  {k}")


if __name__ == "__main__":
    main()
