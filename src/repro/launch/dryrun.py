import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory / cost / collective analysis for §Roofline.

MUST keep the two lines above first: jax locks the device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
Results are one JSON per cell; existing files are skipped (resumable).
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import all_cells, get_arch
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import build_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(r"\b(pred|[sufc]\d+|bf16)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(match) -> int:
    dt, dims = match.group(1), match.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes of collective ops, scaling ops inside while-loops by
    their trip count (scan-over-layers!).  Best-effort static analysis."""
    # split into computations
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->", line.strip())
        if m and ("{" in line or line.strip().endswith("{")):
            if cur_name:
                comps[cur_name] = cur_lines
            cur_name, cur_lines = m.group(1), []
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = cur_lines

    # collective bytes + counts per computation
    per_comp = {}
    for name, lines in comps.items():
        by_op = {}
        for line in lines:
            for op in _COLLECTIVES:
                if re.search(rf"= .*\b{op}(-start|-done)?\(", line):
                    if f"{op}-done" in line:
                        continue  # avoid double count of async pairs
                    b = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(
                        line.split("=", 1)[1].split(f"{op}", 1)[0]))
                    cnt, tot = by_op.get(op, (0, 0))
                    by_op[op] = (cnt + 1, tot + b)
                    break
        per_comp[name] = by_op

    # while-loop trip counts: body/condition linkage
    whiles = []  # (body_name, cond_name, parent)
    for name, lines in comps.items():
        for line in lines:
            m = re.search(r"while\(.*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)", line)
            if not m:
                m2c = re.search(r"condition=%?([\w\.\-]+)", line)
                m2b = re.search(r"body=%?([\w\.\-]+)", line)
                if "while(" in line and m2c and m2b:
                    whiles.append((m2b.group(1), m2c.group(1), name))
                continue
            whiles.append((m.group(2), m.group(1), name))

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = []
        for line in lines:
            for m in re.finditer(r"constant\((\d+)\)", line):
                consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    # attribute body-computation collectives (and anything they call) scaled
    multiplier = {name: 1 for name in comps}
    for body, cond, _parent in whiles:
        t = trip_count(cond)
        if body in multiplier:
            multiplier[body] = max(multiplier[body], t)
    # propagate one level into called computations (fusion/remat wrappers)
    call_re = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")
    for name, lines in comps.items():
        mult = multiplier.get(name, 1)
        if mult <= 1:
            continue
        for line in lines:
            for m in call_re.finditer(line):
                callee = m.group(1)
                if callee in multiplier:
                    multiplier[callee] = max(multiplier[callee], mult)

    total = {op: [0, 0] for op in _COLLECTIVES}
    for name, by_op in per_comp.items():
        mult = multiplier.get(name, 1)
        for op, (cnt, b) in by_op.items():
            total[op][0] += cnt * mult
            total[op][1] += b * mult
    out = {op: {"count": c, "bytes": b} for op, (c, b) in total.items() if c}
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if k in _COLLECTIVES)
    return out


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode D=batch tokens."""
    spec = get_arch(arch)
    if spec.family != "lm":
        return 0.0
    cfg = spec.model
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    shape = spec.shape(shape_name)
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    bundle = build_step(arch, shape_name, mesh)
    t0 = time.time()
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        )
        lowered = jitted.lower(*bundle.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # NOTE: cost_analysis() counts while (scan) bodies once; `analyze` applies
    # trip-count multipliers -- see launch/hlo_analysis.py.
    an = analyze(hlo).as_dict()

    flops_dev = float(an["flops"])
    bytes_dev = float(an["bytes_accessed"])
    coll_bytes_dev = float(an["collective_bytes"])
    compute_s = flops_dev / HW["peak_flops_bf16"]
    memory_s = bytes_dev / HW["hbm_bw"]
    collective_s = coll_bytes_dev / HW["ici_bw"]
    mflops = model_flops(arch, shape_name)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips,
        "kind": bundle.meta.get("kind"),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            k: getattr(mem, k, None) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        } if mem is not None else None,
        "cost_xla_raw": {k: cost.get(k) for k in ("flops", "bytes accessed",
                                                  "transcendentals")},
        "collectives": an["collectives"],
        "n_while": an["n_while"],
        "trip_counts": an["trip_counts"],
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
            "model_flops_total": mflops,
            "hlo_flops_total": flops_dev * n_chips,
            "useful_flops_ratio": (mflops / (flops_dev * n_chips)
                                   if flops_dev else None),
        },
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            path = out_dir / f"{tag}.json"
            if path.exists() and not args.force:
                n_skip += 1
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                res = run_cell(arch, shape, multi)
                path.write_text(json.dumps(res, indent=1))
                r = res["roofline"]
                print(f"[dryrun] {tag} OK compile={res['compile_s']}s "
                      f"dominant={r['dominant']} "
                      f"compute={r['compute_s']:.4g}s mem={r['memory_s']:.4g}s "
                      f"coll={r['collective_s']:.4g}s", flush=True)
                n_ok += 1
            except Exception as e:  # noqa: BLE001
                n_fail += 1
                err = {"arch": arch, "shape": shape,
                       "mesh": "multi" if multi else "single",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                (out_dir / f"{tag}.FAILED.json").write_text(json.dumps(err, indent=1))
                print(f"[dryrun] {tag} FAILED: {type(e).__name__}: {str(e)[:200]}",
                      flush=True)
    print(f"[dryrun] done ok={n_ok} fail={n_fail} skip={n_skip}", flush=True)


if __name__ == "__main__":
    main()
