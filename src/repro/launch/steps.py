"""Step builders: (arch x shape x mesh) -> jit-able fn + abstract inputs + shardings.

Used by the dry-run, the trainer and the server.  Everything here is
allocation-free: inputs are ShapeDtypeStructs (params via ``jax.eval_shape``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, get_arch
from repro.configs.base import GraphShape, LMShape, RecsysShape
from repro.distributed.sharding import (
    ShardingRules,
    base_rules,
    decode_rules,
    tree_shardings,
)
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_axes


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower one (arch x shape) cell on a mesh."""

    fn: Callable                       # positional-arg step function
    abstract_args: Tuple[Any, ...]     # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    rules: ShardingRules
    donate_argnums: Tuple[int, ...] = ()
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _ns(mesh, rules, *axes):
    return NamedSharding(mesh, rules.spec(*axes))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def lm_rules(cfg, shape: LMShape, mesh: Mesh) -> ShardingRules:
    """Config-aware rules: jit in_shardings require divisible dims, so any
    param axis that does not divide evenly falls back to replicated (the
    *activation* constraint can still use uneven GSPMD padding)."""
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    psize = _axis_size(mesh, "pod")
    heads_ok = cfg.n_heads % msize == 0
    kvh_ok = (not cfg.is_mla) and cfg.n_kv_heads % msize == 0

    if shape.kind == "decode":
        b = shape.global_batch
        shard_seq_over_data = b < psize * dsize
        r = decode_rules(mesh, shard_seq_over_data=shard_seq_over_data)
        over = {}
        if shard_seq_over_data:
            # batch too small for any DP axis: replicate batch, spread the KV
            # sequence over every axis (must divide; 512k does)
            kv_axes = tuple(a for a in ("pod", "data", "model")
                            if _axis_size(mesh, a) > 1)
            if b % max(psize, 1) != 0:
                over["batch"] = None
            over["kv_seq"] = kv_axes
        if cfg.is_mla and heads_ok:
            over["heads"] = "model" if msize > 1 else None  # MLA: no GQA reshape
        if not heads_ok:
            over["p_heads"] = None
        if not kvh_ok:
            over["p_kv_heads"] = None
        return r.with_overrides(**over)

    # train / prefill
    fsdp = cfg.fsdp and shape.kind == "train"
    r = base_rules(mesh, fsdp=fsdp)
    over = {}
    if not heads_ok:
        over["p_heads"] = None
    if not kvh_ok:
        over["p_kv_heads"] = None
        over["kv_heads"] = None
    if shape.kind == "prefill":
        # prefill emits the cache seq-sharded so decode can consume it
        over["kv_seq"] = "model" if msize > 1 else None
    return r.with_overrides(**over)


def _lm_bundle(spec: ArchSpec, shape: LMShape, mesh: Mesh,
               extra: Optional[Dict[str, Any]] = None) -> StepBundle:
    cfg = spec.model
    model = build_model(cfg)
    rules = lm_rules(cfg, shape, mesh)
    if extra:
        rules = rules.with_overrides(**extra)
    p_abs = jax.eval_shape(model.init, jax.random.key(0))
    p_shard = tree_shardings(mesh, rules, model.param_axes())
    b, s = shape.global_batch, shape.seq_len
    tok_sh = _ns(mesh, rules, "batch", "seq")

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        o_abs = jax.eval_shape(init_opt_state, p_abs)
        o_shard = tree_shardings(mesh, rules, opt_state_axes(model.param_axes()))
        n_micro = max(1, getattr(cfg, "grad_accum", 1))
        assert b % n_micro == 0, (b, n_micro)
        mb = b // n_micro

        def grads_of(params, tokens, labels):
            return jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, tokens, labels, rules)

        def train_step(params, opt_state, tokens, labels):
            if n_micro == 1:
                (loss, metrics), grads = grads_of(params, tokens, labels)
            else:
                tok_m = tokens.reshape(n_micro, mb, s)
                lab_m = labels.reshape(n_micro, mb, s)

                def micro(carry, xs):
                    g_acc, loss_acc, ce_acc, aux_acc = carry
                    t, l = xs
                    t = jax.lax.with_sharding_constraint(
                        t, rules.spec(None, "batch", "seq"))
                    (loss, met), g = grads_of(params, t, l)
                    g_acc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                    return (g_acc, loss_acc + loss, ce_acc + met["ce"],
                            aux_acc + met["aux"]), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                z = jnp.zeros((), jnp.float32)
                (g_acc, loss, ce, aux), _ = jax.lax.scan(
                    micro, (g0, z, z, z), (tok_m, lab_m))
                inv = 1.0 / n_micro
                grads = jax.tree.map(lambda g: g * inv, g_acc)
                loss, metrics = loss * inv, {"ce": ce * inv, "aux": aux * inv}
            params, opt_state, opt_metrics = adamw_update(
                grads, opt_state, params, opt_cfg)
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return params, opt_state, metrics

        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        met_sh = jax.tree.map(lambda _: _ns(mesh, rules), {
            "ce": 0, "aux": 0, "loss": 0, "grad_norm": 0, "lr": 0})
        return StepBundle(
            fn=train_step,
            abstract_args=(p_abs, o_abs, tok, tok),
            in_shardings=(p_shard, o_shard, tok_sh, tok_sh),
            out_shardings=(p_shard, o_shard, met_sh),
            rules=rules,
            donate_argnums=(0, 1),
            meta={"kind": "train"},
        )

    if shape.kind == "prefill":
        def prefill_step(params, tokens):
            return model.prefill(params, tokens, rules)

        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        cache_sh = jax.tree.map(
            lambda axes: _ns(mesh, rules, *axes), model.cache_axes(),
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(a, (str, type(None))) for a in x))
        logits_sh = _ns(mesh, rules, "batch", "vocab")
        return StepBundle(
            fn=prefill_step,
            abstract_args=(p_abs, tok),
            in_shardings=(p_shard, tok_sh),
            out_shardings=(logits_sh, cache_sh),
            rules=rules,
            meta={"kind": "prefill"},
        )

    # decode
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, rules)

    cache_abs = model.cache_spec(b, s)
    cache_sh = jax.tree.map(
        lambda axes: _ns(mesh, rules, *axes), model.cache_axes(),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_sh1 = _ns(mesh, rules, "batch", None)
    pos_sh = _ns(mesh, rules, "batch")
    logits_sh = _ns(mesh, rules, "batch", "vocab")
    return StepBundle(
        fn=serve_step,
        abstract_args=(p_abs, cache_abs, tok, pos),
        in_shardings=(p_shard, cache_sh, tok_sh1, pos_sh),
        out_shardings=(logits_sh, cache_sh),
        rules=rules,
        donate_argnums=(1,),
        meta={"kind": "decode"},
    )


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def build_step(arch: str, shape_name: str, mesh: Mesh,
               rule_overrides: Optional[Dict[str, Any]] = None,
               cfg_overrides: Optional[Dict[str, Any]] = None) -> StepBundle:
    spec = get_arch(arch)
    if cfg_overrides:
        spec = dataclasses.replace(
            spec, model=dataclasses.replace(spec.model, **cfg_overrides))
    shape = spec.shape(shape_name)
    if spec.family == "lm":
        return _lm_bundle(spec, shape, mesh, rule_overrides)
    if spec.family == "gnn":
        from repro.launch.gnn_steps import gnn_bundle
        return gnn_bundle(spec, shape, mesh, rule_overrides)
    if spec.family == "recsys":
        from repro.launch.recsys_steps import recsys_bundle
        return recsys_bundle(spec, shape, mesh, rule_overrides)
    raise ValueError(f"unknown family {spec.family}")
