"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

import repro.jax_compat  # noqa: F401  (installs AxisType/set_mesh on old jax)
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """1-device mesh with the same axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def make_mesh_for(n_devices: int, *, model_parallel: int = 1):
    """Elastic mesh: whatever devices survive, factored (data, model)."""
    assert n_devices % model_parallel == 0
    return jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


# TPU v5e-ish hardware model used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
}
