"""Static HLO analyzer: roofline terms from a compiled SPMD module.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, which makes
scan-over-layers models look 30-60x cheaper than they are.  This module
re-derives per-device FLOPs / HBM bytes / collective bytes by walking the
post-optimization HLO text with a call-graph multiplier: a while body's
contributions are scaled by its trip count (recovered from the loop-condition
constant).

Byte counting follows XLA's "bytes accessed" convention (operand + result
sizes per op) with corrections where that convention is grossly wrong for a
roofline:
  * dynamic-slice / gather       -> 2x slice size, not the full operand
  * dynamic-update-slice         -> 2x update size (aliased in-place)
  * fusion call sites            -> fusion parameters that are only ever
    sliced inside the fusion count at slice size; in-place DUS roots count at
    update size (this is exactly the scan xs/carry access pattern)

Collectives: result bytes per op, scaled by trip counts, split per opcode.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[sufc]\d+)\[([\d,]*)\]")
# result types may be tuples containing /*index=N*/ comments, so the type
# group must tolerate '='; the opcode is the first bare word followed by '('.
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, Instr]
    is_entry: bool = False


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        if raw and not raw[0].isspace():
            m = _COMP_HDR_RE.match(raw)
            if m:
                cur = Computation(m.group(2), [], {}, is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operands: the %refs inside the top-level parens (before attrs)
        depth, end = 1, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        arg_str = rest[:end]
        operands = _OPERAND_RE.findall(arg_str)
        ins = Instr(name, type_str.strip(), opcode, operands, raw)
        cur.instrs.append(ins)
        cur.symbols[name] = ins
    return comps


def _operand_type(comp: Computation, op_name: str) -> str:
    ins = comp.symbols.get(op_name)
    return ins.type_str if ins else ""


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_dims = _type_dims(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contract = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs_dims = _type_dims(_operand_type(comp, ins.operands[0])) if ins.operands else []
    k = 1
    for c in contract:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * k


def _conv_flops(comp: Computation, ins: Instr) -> float:
    # flops = 2 * prod(result) * (kernel spatial x in_channels / groups)
    out = _type_dims(ins.type_str)
    rhs = _type_dims(_operand_type(comp, ins.operands[1])) if len(ins.operands) > 1 else []
    n_out = 1
    for d in out:
        n_out *= d
    k = 1
    for d in rhs[:-1]:  # kernel dims except output-feature dim (approx)
        k *= d
    return 2.0 * n_out * k


def _instr_bytes(comp: Computation, ins: Instr) -> float:
    op = ins.opcode
    if op in _SKIP_BYTES_OPS:
        return 0.0
    if op in ("dynamic-slice", "gather"):
        return 2.0 * _type_bytes(ins.type_str)
    if op == "dynamic-update-slice":
        upd = _operand_type(comp, ins.operands[1]) if len(ins.operands) > 1 else ""
        return 2.0 * _type_bytes(upd)
    if op == "scatter":
        upd = _operand_type(comp, ins.operands[2]) if len(ins.operands) > 2 else ""
        return 3.0 * _type_bytes(upd)
    total = _type_bytes(ins.type_str)
    for o in ins.operands:
        total += _type_bytes(_operand_type(comp, o))
    return float(total)


def _fusion_bytes(comps: Dict[str, Computation], callee: Computation) -> float:
    """inputs + outputs of a fusion, slice-aware (see module docstring)."""
    total = 0.0
    # parameter access: slice-only params count at slice size
    uses: Dict[str, List[Instr]] = {}
    for ins in callee.instrs:
        for o in ins.operands:
            uses.setdefault(o, []).append(ins)
    root = callee.instrs[-1] if callee.instrs else None
    for ins in callee.instrs:
        if ins.opcode != "parameter":
            continue
        us = uses.get(ins.name, [])
        if us and all(u.opcode in ("dynamic-slice", "gather") for u in us):
            total += sum(_type_bytes(u.type_str) for u in us)
        else:
            total += _type_bytes(ins.type_str)
    if root is not None:
        if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            total += 2.0 * _type_bytes(_operand_type(callee, root.operands[1]))
        else:
            total += _type_bytes(root.type_str)
    return total


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


_CALL_ATTRS = re.compile(
    r"(?:condition|body|calls|to_apply|true_computation|false_computation|"
    r"branch_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_counts: List[int] = dataclasses.field(default_factory=list)
    # profiling: top contributors keyed by "opcode shape" (trip-scaled)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    flops_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_by_shape: Dict[str, float] = dataclasses.field(default_factory=dict)

    def top_bytes(self, n: int = 12) -> List[Tuple[str, float]]:
        return sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:n]

    def top_flops(self, n: int = 12) -> List[Tuple[str, float]]:
        return sorted(self.flops_by_op.items(), key=lambda kv: -kv[1])[:n]

    def top_collectives(self, n: int = 12) -> List[Tuple[str, float]]:
        return sorted(self.coll_by_shape.items(), key=lambda kv: -kv[1])[:n]

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "n_while": self.n_while,
            "trip_counts": sorted(self.trip_counts, reverse=True)[:12],
            "top_bytes": self.top_bytes(),
            "top_flops": self.top_flops(),
            "top_collectives": self.top_collectives(),
        }


def analyze(hlo_text: str) -> Analysis:
    comps = parse_module(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: largest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs), default=None)
    out = Analysis()
    if entry is None:
        return out

    seen_stack: List[str] = []

    def visit(comp: Computation, mult: float, bytes_mode: bool) -> None:
        if comp.name in seen_stack:  # cycles should not happen; guard anyway
            return
        seen_stack.append(comp.name)
        for ins in comp.instrs:
            op = ins.opcode
            shape_key = ins.type_str.split("{")[0].strip()
            if op == "dot":
                f = mult * _dot_flops(comp, ins)
                out.flops += f
                k = f"dot {shape_key}"
                out.flops_by_op[k] = out.flops_by_op.get(k, 0.0) + f
            elif op == "convolution":
                out.flops += mult * _conv_flops(comp, ins)
            if op in _COLLECTIVE_OPS:
                b = _type_bytes(ins.type_str)
                key = op.replace("-start", "")
                ent = out.collectives.setdefault(key, {"count": 0, "bytes": 0.0})
                ent["count"] += mult
                ent["bytes"] += mult * b
                out.collective_bytes += mult * b
                ck = f"{key} {shape_key}"
                out.coll_by_shape[ck] = out.coll_by_shape.get(ck, 0.0) + mult * b
            # --- bytes ---
            if bytes_mode:
                if op == "fusion":
                    callee_m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                    callee = comps.get(callee_m.group(1)) if callee_m else None
                    if callee is not None:
                        fb = mult * _fusion_bytes(comps, callee)
                        out.bytes_accessed += fb
                        k = f"fusion {shape_key}"
                        out.bytes_by_op[k] = out.bytes_by_op.get(k, 0.0) + fb
                        # recurse for flops only (dots inside fusions)
                        visit(callee, mult, bytes_mode=False)
                    continue
                if op not in ("while", "call", "conditional"):
                    ib = mult * _instr_bytes(comp, ins)
                    out.bytes_accessed += ib
                    if ib:
                        k = f"{op} {shape_key}"
                        out.bytes_by_op[k] = out.bytes_by_op.get(k, 0.0) + ib
            elif op == "fusion":
                callee_m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                callee = comps.get(callee_m.group(1)) if callee_m else None
                if callee is not None:
                    visit(callee, mult, bytes_mode=False)
            # --- control flow ---
            if op == "while":
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                trip = _trip_count(comps, mc.group(1)) if mc else 1
                out.n_while += 1
                out.trip_counts.append(trip)
                if mb and mb.group(1) in comps:
                    visit(comps[mb.group(1)], mult * trip, bytes_mode)
            elif op in ("call", "conditional", "async-start"):
                for mm in _CALL_ATTRS.finditer(ins.line):
                    for callee_name in re.split(r",\s*%?", mm.group(1)):
                        callee = comps.get(callee_name)
                        if callee is not None and "condition" not in mm.group(0):
                            visit(callee, mult, bytes_mode)
        seen_stack.pop()

    visit(entry, 1.0, bytes_mode=True)
    return out
