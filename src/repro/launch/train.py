"""Production train launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --shape train_4k \
      --steps 10 [--devices 512] [--smoke]

On real hardware this runs the lowered bundle from steps.py step-by-step
with checkpoint/restart; on this CPU container use --smoke to run a reduced
config of the same arch end-to-end (the full configs are dry-run only)."""
import os

if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_FORCE_DEVICES']}")

import argparse
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import reduced
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.distributed.sharding import base_rules
from repro.launch.mesh import make_smoke_mesh
from repro.training.train_loop import TrainLoopConfig, run_train_loop


def smoke_config(arch: str):
    spec = get_arch(arch)
    cfg = spec.model
    if spec.family != "lm":
        raise SystemExit("--smoke currently supports LM archs; "
                         "see examples/ for GNN/recsys drivers")
    overrides = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                     head_dim=32, d_ff=256, vocab_size=512, dtype="float32",
                     grad_accum=1, fsdp=False)
    if cfg.is_moe:
        overrides.update(n_routed_experts=8, n_shared_experts=1, top_k=2,
                         moe_d_ff=64, n_kv_heads=4)
    if cfg.is_mla:
        overrides.update(kv_lora_rank=32, q_lora_rank=64, qk_nope_head_dim=32,
                         qk_rope_head_dim=16, v_head_dim=32, n_kv_heads=4)
    return reduced(cfg, **overrides)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    from repro.models.transformer import LM
    model = LM(cfg)
    mesh = make_smoke_mesh()
    rules = base_rules(mesh)
    params = model.init(jax.random.key(0))
    data = SyntheticLM(LMDataConfig(cfg.vocab_size, args.seq, args.batch))

    def loss_fn(p, batch):
        loss, _ = model.loss_fn(p, batch["tokens"], batch["labels"], rules)
        return loss

    with jax.set_mesh(mesh):
        out = run_train_loop(
            loss_fn, params, data.batches(args.steps + 1),
            TrainLoopConfig(n_steps=args.steps, ckpt_dir=args.ckpt_dir),
            meta={"arch": args.arch, "smoke": True})
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"wall {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
