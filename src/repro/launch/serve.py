"""Serving launcher: stand up a PandaDB with extractors + index and serve a
mixed CypherPlus workload (Fig 8's harness as a CLI).

  PYTHONPATH=src python -m repro.launch.serve --persons 200 --clients 8

Cluster modes (paper §VII-A):

  # sharded:
  PYTHONPATH=src python -m repro.launch.serve --shards 4
  # replicated + chaos: a replica is fail-stopped mid-run; the server must
  # stay up (failover + hedged reads mask it) and reports what it did
  PYTHONPATH=src python -m repro.launch.serve --shards 2 --replicas 2 --chaos

Overload mode (deadlines + admission control, §VII overload regime):

  # open-loop at ~2x measured capacity with per-request deadlines and a
  # bounded queue; prints goodput and the shed/expired/degraded/breaker
  # counters so the load-shedding path is observable from the CLI
  PYTHONPATH=src python -m repro.launch.serve --overload --deadline-ms 100
"""
import argparse
import json
import threading

import numpy as np

from repro.cluster import FaultInjector, ReplicatedPandaDB, ShardedPandaDB
from repro.configs.pandadb import PandaDBConfig, ServingConfig, VectorIndexConfig
from repro.core import PandaDB
from repro.core.aipm import feature_hash_extractor, label_extractor
from repro.data.synthetic_graph import SNBConfig, build_snb
from repro.obs import prometheus_dump
from repro.serving.engine import QueryServer


def build_db(n_persons: int) -> PandaDB:
    db = PandaDB()
    db.register_extractor("face", feature_hash_extractor(dim=64))
    db.register_extractor("animal", label_extractor(["cat", "dog", "bird"]))
    build_snb(db, SNBConfig(n_persons=n_persons,
                            n_identities=max(2, n_persons // 3)))
    db.build_index("face", "photo")
    return db


def build_cluster(n_persons: int, n_shards: int, replicas: int,
                  faults: FaultInjector):
    """Cluster population goes through the coordinator's routed write path
    (``build_snb`` writes straight into a single node's graph store)."""
    if replicas > 1:
        db = ReplicatedPandaDB(n_shards=n_shards, replication=replicas,
                               faults=faults)
    else:
        db = ShardedPandaDB(n_shards=n_shards)
    rng = np.random.default_rng(0)
    for i in range(n_persons):
        nid = db.create_node("Person", name=f"person_{i}",
                             age=float(20 + i % 50),
                             photo=rng.bytes(256))
        if i:
            db.create_relationship(nid - 1, nid, "knows")
    db.register_extractor("face", feature_hash_extractor(dim=64))
    db.build_index("face", "photo")
    return db


QUERIES = [
    "MATCH (n:Person)-[:workFor]->(t:Team) WHERE n.name='person_3' RETURN t.name",
    "MATCH (n:Person) WHERE n.age > 40 RETURN n.name LIMIT 5",
    "MATCH (n:Person)-[:knows]->(m:Person) WHERE n.name='person_1' RETURN m.name",
    "MATCH (n:Person), (m:Person) WHERE n.name='person_2' "
    "AND n.photo->face ~: m.photo->face RETURN m.name",
]

#: single-anchor pipelines only: cluster fan-out cannot read a non-anchor
#: node's properties (they live on that node's owner shard)
CLUSTER_QUERIES = [
    "MATCH (n:Person) WHERE n.age > 40 RETURN n.name LIMIT 5",
    "MATCH (n:Person) WHERE n.name = 'person_1' RETURN n.age",
    ("MATCH (p:Person) WHERE p = $id RETURN p.name", {"id": 3}),
    "MATCH (n:Person)-[:knows]->(m:Person) WHERE n.age > 60 "
    "RETURN n.name, m.__self__",
]


def run_overload(db, queries, args) -> None:
    """Measure closed-loop capacity, then offer ~2x open-loop with
    per-request deadlines and a bounded admission queue; print goodput and
    every overload counter (plus breaker states on a replicated cluster)."""
    probe = QueryServer(db, n_workers=args.workers)
    cap = probe.run_closed_loop(queries, n_clients=args.clients,
                                duration_s=max(1.0, args.duration / 2))
    capacity_qps = cap.throughput_qps
    print(json.dumps({"capacity_qps": capacity_qps}, indent=1))

    serving = ServingConfig(queue_depth=args.queue_depth,
                            admission_policy="reject",
                            default_deadline_ms=args.deadline_ms,
                            shed_on_arrival=True)
    server = QueryServer(db, n_workers=args.workers, serving=serving)
    summary = server.run_open_loop(
        queries, rate_qps=max(2.0, 2.0 * capacity_qps),
        duration_s=args.duration, deadline_ms=args.deadline_ms)
    server.close()
    print("overload:", json.dumps(summary, indent=1))
    print("counters:", json.dumps(server.route_counts(), indent=1))
    if args.metrics:
        print(prometheus_dump(), end="")
    if hasattr(db, "close"):
        db.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--persons", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--shards", type=int, default=0,
                    help="serve a sharded cluster with this many shards")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replicas per shard (with --shards)")
    ap.add_argument("--chaos", action="store_true",
                    help="fail-stop shard 0 replica 0 mid-run (needs "
                         "--replicas >= 2)")
    ap.add_argument("--overload", action="store_true",
                    help="open-loop overload mode: measure capacity, then "
                         "offer ~2x with per-request deadlines + admission "
                         "control and print shed/expired/degraded counters")
    ap.add_argument("--deadline-ms", type=float, default=100.0,
                    help="per-request budget in --overload mode")
    ap.add_argument("--queue-depth", type=int, default=32,
                    help="admission queue bound in --overload mode")
    ap.add_argument("--metrics", action="store_true",
                    help="print a Prometheus-style text dump of every live "
                         "metrics registry after the run")
    args = ap.parse_args()

    if args.chaos and args.replicas < 2:
        ap.error("--chaos needs --replicas >= 2 (a lone replica cannot "
                 "fail over)")

    if args.shards > 0:
        faults = FaultInjector(seed=0)
        db = build_cluster(args.persons, args.shards, args.replicas, faults)
        queries = CLUSTER_QUERIES
    else:
        db = build_db(args.persons)
        queries = QUERIES

    if args.overload:
        run_overload(db, queries, args)
        return

    server = QueryServer(db, n_workers=args.workers)
    killer = None
    if args.chaos:
        killer = threading.Timer(args.duration / 2,
                                 faults.fail_stop, args=(0, 0))
        killer.start()
    stats = server.run_closed_loop(queries, n_clients=args.clients,
                                   duration_s=args.duration)
    if killer is not None:
        killer.cancel()
    print(json.dumps(stats.summary(), indent=1))
    if args.shards > 0:
        print("routing:", json.dumps(server.route_counts(), indent=1))
    else:
        print("cache:", db.cache.stats())
    if args.metrics:
        print(prometheus_dump(), end="")
    if args.shards > 0:
        db.close()


if __name__ == "__main__":
    main()
