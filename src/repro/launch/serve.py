"""Serving launcher: stand up a PandaDB with extractors + index and serve a
mixed CypherPlus workload (Fig 8's harness as a CLI).

  PYTHONPATH=src python -m repro.launch.serve --persons 200 --clients 8
"""
import argparse
import json

import numpy as np

from repro.configs.pandadb import PandaDBConfig, VectorIndexConfig
from repro.core import PandaDB
from repro.core.aipm import feature_hash_extractor, label_extractor
from repro.data.synthetic_graph import SNBConfig, build_snb
from repro.serving.engine import QueryServer


def build_db(n_persons: int) -> PandaDB:
    db = PandaDB()
    db.register_extractor("face", feature_hash_extractor(dim=64))
    db.register_extractor("animal", label_extractor(["cat", "dog", "bird"]))
    build_snb(db, SNBConfig(n_persons=n_persons,
                            n_identities=max(2, n_persons // 3)))
    db.build_index("face", "photo")
    return db


QUERIES = [
    "MATCH (n:Person)-[:workFor]->(t:Team) WHERE n.name='person_3' RETURN t.name",
    "MATCH (n:Person) WHERE n.age > 40 RETURN n.name LIMIT 5",
    "MATCH (n:Person)-[:knows]->(m:Person) WHERE n.name='person_1' RETURN m.name",
    "MATCH (n:Person), (m:Person) WHERE n.name='person_2' "
    "AND n.photo->face ~: m.photo->face RETURN m.name",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--persons", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    db = build_db(args.persons)
    server = QueryServer(db, n_workers=args.workers)
    stats = server.run_closed_loop(QUERIES, n_clients=args.clients,
                                   duration_s=args.duration)
    print(json.dumps(stats.summary(), indent=1))
    print("cache:", db.cache.stats())


if __name__ == "__main__":
    main()
