"""GNN step bundles: every GNN shape reduces to one edge-list training step.

  * full_graph / full-batch-large : (feats, [pos], src, dst, mask, labels)
  * minibatch                     : the sampled block-graph (same layout;
                                    loss only on the first `batch_nodes` seeds)
  * molecule (batched)            : graphs flattened with offsets + graph_ids,
                                    MSE on a mean-readout target

Padding: node/edge counts are padded up so every sharded dim divides the
mesh (recorded in `meta`); padded edges carry mask=False.  Geometric models
(SchNet / Equiformer) receive positions; on non-molecular graphs these are
synthetic coordinates (documented in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec
from repro.configs.base import GraphShape
from repro.distributed.sharding import ShardingRules, base_rules, tree_shardings
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_axes


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def gnn_rules(mesh: Mesh, *, shard_nodes: bool, channel_shard: bool
              ) -> ShardingRules:
    r = base_rules(mesh)
    has = lambda a: a in mesh.axis_names and mesh.shape[a] > 1  # noqa: E731
    over: Dict[str, Any] = {
        "edge": "data" if has("data") else None,
        "node": (tuple(a for a in ("data", "model") if has(a)) or None)
        if shard_nodes else None,
        "channel": ("model" if (channel_shard and has("model")) else None),
        "channel_out": None,
        "graph": (tuple(a for a in ("pod", "data") if has(a)) or None),
    }
    return r.with_overrides(**over)


@dataclasses.dataclass
class GNNCell:
    n_nodes: int
    n_edges: int
    d_feat: int
    n_out: int
    needs_pos: bool
    shard_nodes: bool
    channel_shard: bool
    chunk: Optional[int]
    graph_level: bool = False
    n_graphs: int = 0
    seeds: int = 0                      # minibatch: loss on first `seeds` nodes


def cell_of(spec: ArchSpec, shape: GraphShape, mesh: Mesh) -> GNNCell:
    cfg = spec.model
    kind = cfg.kind
    needs_pos = kind in ("schnet", "equiformer_v2")
    big = shape.n_nodes > 500_000
    d_shard = max(
        (mesh.shape["data"] if "data" in mesh.axis_names else 1), 1)
    total = mesh.size

    if shape.kind == "batched":      # molecule
        g = shape.batch_graphs
        n_nodes = g * shape.n_nodes
        n_edges = _pad_to(g * shape.n_edges, 512)
        return GNNCell(n_nodes=n_nodes, n_edges=n_edges, d_feat=100,
                       n_out=1, needs_pos=needs_pos, shard_nodes=False,
                       channel_shard=(kind == "equiformer_v2"), chunk=None,
                       graph_level=True, n_graphs=g)
    if shape.kind == "minibatch":
        b = shape.batch_nodes
        f1, f2 = shape.fanout
        n_nodes = b * (1 + f1 + f1 * f2)
        n_edges = b * f1 + b * f1 * f2
        chunk = None
        if kind == "equiformer_v2":
            chunk = _pick_chunk(n_edges, d_shard)
        return GNNCell(n_nodes=_pad_to(n_nodes, 512),
                       n_edges=_pad_to(n_edges, 512 if chunk is None else chunk),
                       d_feat=shape.d_feat, n_out=spec.model.n_classes,
                       needs_pos=needs_pos, shard_nodes=False,
                       channel_shard=(kind == "equiformer_v2"),
                       chunk=chunk, seeds=b)
    # full graph
    chunk = None
    if kind == "equiformer_v2" and shape.n_edges > 1_000_000:
        chunk = _pick_chunk(shape.n_edges, d_shard)
    n_edges = _pad_to(shape.n_edges, 512 if chunk is None else chunk)
    shard_nodes = big and kind != "equiformer_v2"
    return GNNCell(
        n_nodes=_pad_to(shape.n_nodes, total * 2) if shard_nodes else shape.n_nodes,
        n_edges=n_edges, d_feat=shape.d_feat, n_out=spec.model.n_classes,
        needs_pos=needs_pos, shard_nodes=shard_nodes,
        channel_shard=(kind == "equiformer_v2"), chunk=chunk)


def _pick_chunk(n_edges: int, d_shard: int) -> int:
    """Chunk divisible by the data axis; ~32k edges per chunk."""
    base = 32_768
    while base % d_shard:
        base *= 2
    return base


def gnn_bundle(spec: ArchSpec, shape: GraphShape, mesh: Mesh,
               rule_overrides: Optional[Dict[str, Any]] = None):
    from repro.launch.steps import StepBundle  # local import to avoid cycle

    cfg = spec.model
    model = build_model(cfg)
    cell = cell_of(spec, shape, mesh)
    rules = gnn_rules(mesh, shard_nodes=cell.shard_nodes,
                      channel_shard=cell.channel_shard)
    if rule_overrides:
        rules = rules.with_overrides(**rule_overrides)

    feat_dtype = jnp.bfloat16 if cell.n_nodes > 500_000 else jnp.float32
    p_abs = jax.eval_shape(
        lambda k: model.init(k, cell.d_feat, cell.n_out), jax.random.key(0))
    p_axes = model.param_axes()
    p_shard = tree_shardings(mesh, rules, p_axes)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    o_abs = jax.eval_shape(init_opt_state, p_abs)
    o_shard = tree_shardings(mesh, rules, opt_state_axes(p_axes))

    n, e = cell.n_nodes, cell.n_edges
    batch_abs: Dict[str, Any] = {
        "feats": jax.ShapeDtypeStruct((n, cell.d_feat), feat_dtype),
        "src": jax.ShapeDtypeStruct((e,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((e,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((e,), jnp.bool_),
    }
    batch_sh: Dict[str, Any] = {
        "feats": NamedSharding(mesh, rules.spec("node", None)),
        "src": NamedSharding(mesh, rules.spec("edge")),
        "dst": NamedSharding(mesh, rules.spec("edge")),
        "edge_mask": NamedSharding(mesh, rules.spec("edge")),
    }
    if cell.needs_pos:
        batch_abs["pos"] = jax.ShapeDtypeStruct((n, 3), jnp.float32)
        batch_sh["pos"] = NamedSharding(mesh, rules.spec("node", None))
    if cell.graph_level:
        batch_abs["graph_ids"] = jax.ShapeDtypeStruct((n,), jnp.int32)
        batch_abs["target"] = jax.ShapeDtypeStruct((cell.n_graphs,), jnp.float32)
        batch_sh["graph_ids"] = NamedSharding(mesh, rules.spec("node"))
        batch_sh["target"] = NamedSharding(mesh, rules.spec(None))
    else:
        batch_abs["labels"] = jax.ShapeDtypeStruct((n,), jnp.int32)
        batch_sh["labels"] = NamedSharding(mesh, rules.spec("node"))

    compute_dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))

    def loss_fn(params, batch):
        pos = batch.get("pos", jnp.zeros((n, 3), jnp.float32))
        logits = model.node_logits(
            params, batch["feats"].astype(compute_dtype), pos,
            batch["src"], batch["dst"],
            batch["edge_mask"].astype(jnp.float32), n,
            **({"chunk": cell.chunk} if cell.chunk else {}))
        if cell.graph_level:
            num = jax.ops.segment_sum(logits[:, 0], batch["graph_ids"],
                                      cell.n_graphs)
            cnt = jax.ops.segment_sum(jnp.ones(n), batch["graph_ids"],
                                      cell.n_graphs)
            pred = num / jnp.maximum(cnt, 1.0)
            return jnp.mean(jnp.square(pred - batch["target"])), pred
        labels = batch["labels"]
        valid = labels >= 0
        if cell.seeds:
            valid = valid & (jnp.arange(n) < cell.seeds)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
        ce = jnp.where(valid, lse - ll, 0.0)
        return jnp.sum(ce) / jnp.maximum(jnp.sum(valid), 1.0), lse

    def train_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    met_sh = {"loss": NamedSharding(mesh, P()),
              "grad_norm": NamedSharding(mesh, P()),
              "lr": NamedSharding(mesh, P())}
    return StepBundle(
        fn=train_step,
        abstract_args=(p_abs, o_abs, batch_abs),
        in_shardings=(p_shard, o_shard, batch_sh),
        out_shardings=(p_shard, o_shard, met_sh),
        rules=rules,
        donate_argnums=(0, 1),
        meta={"kind": "gnn_train", "cell": dataclasses.asdict(cell)},
    )
