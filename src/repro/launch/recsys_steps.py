"""RecSys step bundles (AutoInt x 4 shapes).

  * train_batch     -> train_step (BCE + AdamW) on [65536, F, H] multi-hot ids
  * serve_p99/bulk  -> forward scoring
  * retrieval_cand  -> 1 query vs 1M sharded candidate representations,
                       local dot + top-k + merge (the vector-index schedule)

Tables are field-sharded over ``model`` (39 fields padded to a multiple of
the axis); batch over (pod, data); candidates over (data, model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec
from repro.configs.base import RecsysShape
from repro.distributed.sharding import ShardingRules, base_rules, tree_shardings
from repro.models.recsys.autoint import AutoInt
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_axes


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def recsys_rules(mesh: Mesh) -> ShardingRules:
    r = base_rules(mesh)
    has = lambda a: a in mesh.axis_names and mesh.shape[a] > 1  # noqa: E731
    return r.with_overrides(
        field="model" if has("model") else None,
        candidate=(tuple(a for a in ("data", "model") if has(a)) or None),
    )


def recsys_bundle(spec: ArchSpec, shape: RecsysShape, mesh: Mesh,
                  rule_overrides: Optional[Dict[str, Any]] = None):
    from repro.launch.steps import StepBundle

    cfg = spec.model
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    f_pad = _pad_to(cfg.n_sparse, max(msize, 1))
    model = AutoInt(cfg, n_fields_padded=f_pad)
    rules = recsys_rules(mesh)
    if rule_overrides:
        rules = rules.with_overrides(**rule_overrides)

    p_abs = jax.eval_shape(model.init, jax.random.key(0))
    p_shard = tree_shardings(mesh, rules, model.param_axes())
    field_mask = jnp.zeros((f_pad,))  # placeholder; built inside the step
    h = cfg.multi_hot
    b = shape.batch

    ids_abs = jax.ShapeDtypeStruct((b, f_pad, h), jnp.int32)
    ids_sh = NamedSharding(mesh, rules.spec("batch", None, None))

    def fmask():
        return (jnp.arange(f_pad) < cfg.n_sparse).astype(jnp.float32)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
        o_abs = jax.eval_shape(init_opt_state, p_abs)
        o_shard = tree_shardings(mesh, rules,
                                 opt_state_axes(model.param_axes()))
        lab_abs = jax.ShapeDtypeStruct((b,), jnp.float32)
        lab_sh = NamedSharding(mesh, rules.spec("batch"))

        def train_step(params, opt_state, ids, labels):
            loss, grads = jax.value_and_grad(model.loss_fn)(
                params, ids, labels, fmask())
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {"loss": loss, **om}

        met_sh = {k: NamedSharding(mesh, P()) for k in
                  ("loss", "grad_norm", "lr")}
        return StepBundle(
            fn=train_step,
            abstract_args=(p_abs, o_abs, ids_abs, lab_abs),
            in_shardings=(p_shard, o_shard, ids_sh, lab_sh),
            out_shardings=(p_shard, o_shard, met_sh),
            rules=rules, donate_argnums=(0, 1),
            meta={"kind": "recsys_train", "f_pad": f_pad},
        )

    if shape.kind == "serve":
        def serve_step(params, ids):
            return model.logits(params, ids, fmask())

        return StepBundle(
            fn=serve_step,
            abstract_args=(p_abs, ids_abs),
            in_shardings=(p_shard, ids_sh),
            out_shardings=NamedSharding(mesh, rules.spec("batch")),
            rules=rules,
            meta={"kind": "recsys_serve", "f_pad": f_pad},
        )

    # retrieval: 1 query against n_candidates item representations
    n_cand = _pad_to(shape.n_candidates, mesh.size * 2)
    d_repr = model.d_repr
    cand_abs = jax.ShapeDtypeStruct((n_cand, d_repr), jnp.float32)
    cand_sh = NamedSharding(mesh, rules.spec("candidate", None))
    qids_abs = jax.ShapeDtypeStruct((1, f_pad, h), jnp.int32)
    qids_sh = NamedSharding(mesh, rules.spec(None, None, None))

    def retrieval_step(params, query_ids, cand_reps):
        return model.score_candidates(params, query_ids, cand_reps, k=100,
                                      field_mask=fmask())

    out_sh = (NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    return StepBundle(
        fn=retrieval_step,
        abstract_args=(p_abs, qids_abs, cand_abs),
        in_shardings=(p_shard, qids_sh, cand_sh),
        out_shardings=out_sh,
        rules=rules,
        meta={"kind": "recsys_retrieval", "n_cand": n_cand},
    )
