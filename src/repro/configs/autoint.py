"""autoint [recsys] n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2 d_attn=32.

interaction=self-attn [arXiv:1810.11921; paper].  39 sparse fields = Criteo's
13 dense-as-bucketized + 26 categorical convention.
"""
from repro.configs.base import ArchSpec, RecsysConfig, recsys_shapes

ARCH = ArchSpec(
    name="autoint",
    family="recsys",
    model=RecsysConfig(
        kind="autoint",
        n_sparse=39,
        embed_dim=16,
        n_attn_layers=3,
        n_heads=2,
        d_attn=32,
        vocab_per_field=1_000_000,
        multi_hot=4,
    ),
    shapes=recsys_shapes(),
    source="arXiv:1810.11921; paper",
)
