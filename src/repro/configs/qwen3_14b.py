"""qwen3-14b [dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
"""
from repro.configs.base import ArchSpec, TransformerConfig, lm_shapes

ARCH = ArchSpec(
    name="qwen3-14b",
    family="lm",
    model=TransformerConfig(
        n_layers=40,
        d_model=5_120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17_408,
        vocab_size=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        fsdp=True,
        grad_accum=4,
    ),
    shapes=lm_shapes(),
    source="hf:Qwen/Qwen3-8B; hf",
)
