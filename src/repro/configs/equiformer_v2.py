"""equiformer-v2 [gnn] n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8.

Equivariant graph-attention via eSCN SO(2) convolutions [arXiv:2306.12059].
"""
from repro.configs.base import ArchSpec, GNNConfig, gnn_shapes

ARCH = ArchSpec(
    name="equiformer-v2",
    family="gnn",
    model=GNNConfig(
        kind="equiformer_v2",
        n_layers=12,
        d_hidden=128,
        l_max=6,
        m_max=2,
        n_heads=8,
        n_rbf=128,
        cutoff=12.0,
    ),
    shapes=gnn_shapes(),
    source="arXiv:2306.12059; unverified",
)
