"""deepseek-v2-236b [moe] 60L d_model=5120 128H d_ff=1536 vocab=102400.

MoE 160e top-6, MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].  MLA: q_lora_rank=1536, qk_nope=128, qk_rope=64,
v_head=128.  First layer dense with d_ff=12288 (upstream convention).
"""
from repro.configs.base import ArchSpec, TransformerConfig, lm_shapes

ARCH = ArchSpec(
    name="deepseek-v2-236b",
    family="lm",
    model=TransformerConfig(
        n_layers=60,
        d_model=5_120,
        n_heads=128,
        n_kv_heads=128,           # MLA: all heads share the latent KV
        d_ff=12_288,              # first dense layer
        moe_d_ff=1_536,           # per routed/shared expert
        vocab_size=102_400,
        n_routed_experts=160,
        n_shared_experts=2,
        top_k=6,
        first_dense_layers=1,
        kv_lora_rank=512,
        q_lora_rank=1_536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        rope_theta=10_000.0,
        fsdp=True,
        grad_accum=16,
    ),
    shapes=lm_shapes(),
    source="arXiv:2405.04434; hf",
)
