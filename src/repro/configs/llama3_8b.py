"""llama3-8b [dense] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

GQA 128k vocab [arXiv:2407.21783; unverified].
"""
from repro.configs.base import ArchSpec, TransformerConfig, lm_shapes

ARCH = ArchSpec(
    name="llama3-8b",
    family="lm",
    model=TransformerConfig(
        n_layers=32,
        d_model=4_096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        vocab_size=128_256,
        rope_theta=500_000.0,
        fsdp=True,
        grad_accum=4,
    ),
    shapes=lm_shapes(),
    source="arXiv:2407.21783; unverified",
)
