"""gin (BONUS arch from the public pool) [arXiv:1810.00826]:
sum-aggregation + eps + MLP.  Selectable via --arch gin-bonus."""
from repro.configs.base import ArchSpec, GNNConfig, gnn_shapes

ARCH = ArchSpec(
    name="gin-bonus",
    family="gnn",
    model=GNNConfig(kind="gin", n_layers=5, d_hidden=64, n_classes=7),
    shapes=gnn_shapes(),
    source="arXiv:1810.00826; paper (bonus)",
)
