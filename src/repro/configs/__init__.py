"""Architecture registry: ``get_arch("llama3-8b")`` resolves an ArchSpec."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    ArchSpec,
    GNNConfig,
    GraphShape,
    LMShape,
    RecsysConfig,
    RecsysShape,
    TransformerConfig,
    gnn_shapes,
    lm_shapes,
    recsys_shapes,
    reduced,
)

_ARCH_MODULES = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "llama3-8b": "repro.configs.llama3_8b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "gcn-cora": "repro.configs.gcn_cora",
    "schnet": "repro.configs.schnet",
    "autoint": "repro.configs.autoint",
    # bonus archs from the public pool (not in the assigned 40-cell grid)
    "gat-bonus": "repro.configs.gat_bonus",
    "gin-bonus": "repro.configs.gin_bonus",
}

ASSIGNED = [n for n in _ARCH_MODULES if not n.endswith("-bonus")]


def arch_names() -> List[str]:
    return list(_ARCH_MODULES)


def get_arch(name: str) -> ArchSpec:
    try:
        mod = importlib.import_module(_ARCH_MODULES[name])
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}") from None
    return mod.ARCH


def all_cells() -> List[tuple]:
    """Every ASSIGNED (arch, shape) pair -- the 40 dry-run cells."""
    cells = []
    for name in ASSIGNED:
        spec = get_arch(name)
        for shape_name in spec.shapes:
            cells.append((name, shape_name))
    return cells
