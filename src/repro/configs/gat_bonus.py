"""gat (BONUS arch from the public pool) [arXiv:1710.10903]:
8-head graph attention, the SDDMM/edge-softmax kernel regime.
Not part of the assigned 40-cell grid; selectable via --arch gat-bonus."""
from repro.configs.base import ArchSpec, GNNConfig, gnn_shapes

ARCH = ArchSpec(
    name="gat-bonus",
    family="gnn",
    model=GNNConfig(kind="gat", n_layers=2, d_hidden=8, n_heads=8,
                    n_classes=7),
    shapes=gnn_shapes(),
    source="arXiv:1710.10903; paper (bonus)",
)
