"""Config system: dataclasses for architectures, input shapes, meshes, training.

Every assigned architecture gets one module in ``repro.configs`` exporting
``ARCH`` (an :class:`ArchSpec`).  The registry in ``repro.configs.__init__``
resolves ``--arch <id>`` strings.

Shapes are *first-class*: each architecture carries its own shape set, so a
(arch x shape) cell is fully defined by ``get_arch(name).shapes[shape_name]``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LMShape:
    """seq_len x global_batch cell for LM-family transformers.

    ``kind``:
      * ``train``   -> lowers ``train_step`` (fwd+bwd+optimizer)
      * ``prefill`` -> lowers ``prefill_step`` (forward, builds KV cache)
      * ``decode``  -> lowers ``serve_step`` (1 new token, KV cache of seq_len)
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclass(frozen=True)
class GraphShape:
    """GNN cell. ``kind``:

      * ``full_graph`` -> full-batch training step on one big graph
      * ``minibatch``  -> sampled-subgraph training step (needs neighbor sampler)
      * ``batched``    -> batch of small graphs (molecules)
    """

    name: str
    kind: str  # full_graph | minibatch | batched
    n_nodes: int
    n_edges: int
    d_feat: int = 0
    batch_nodes: int = 0          # minibatch only
    fanout: Tuple[int, ...] = ()  # minibatch only
    batch_graphs: int = 0         # batched only


@dataclass(frozen=True)
class RecsysShape:
    """RecSys cell. ``kind``:

      * ``train``     -> train_step on a batch of (dense, sparse) features
      * ``serve``     -> inference scoring of a batch
      * ``retrieval`` -> score 1 query against ``n_candidates`` (batched-dot / ANN)
    """

    name: str
    kind: str  # train | serve | retrieval
    batch: int
    n_candidates: int = 0


Shape = Any  # LMShape | GraphShape | RecsysShape


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TransformerConfig:
    """Decoder-only LM; covers dense, GQA, qk-norm, fine-grained MoE and MLA."""

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE (0 routed experts == dense) ---
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden size (fine-grained)
    capacity_factor: float = 1.25
    first_dense_layers: int = 1       # DeepSeek keeps layer 0 dense
    # --- MLA (kv_lora_rank > 0 enables it) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- numerics / scale ---
    dtype: str = "bfloat16"
    fsdp: bool = False                # shard params over the data axis too
    remat: bool = True
    grad_accum: int = 1               # microbatches per train step
    attn_block_q: int = 512           # chunked-attention block sizes (XLA path)
    attn_block_kv: int = 1024
    fused_norm: bool = False          # §Perf: no fp32 materialization in norms
    bf16_probs: bool = False          # §Perf: bf16 softmax weights in attention

    @property
    def is_moe(self) -> bool:
        return self.n_routed_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.is_mla:
            qdim = self.qk_nope_head_dim + self.qk_rope_head_dim
            attn = 0
            if self.q_lora_rank:
                attn += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qdim
            else:
                attn += d * self.n_heads * qdim
            attn += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            attn += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            attn += self.n_heads * self.v_head_dim * d
        else:
            attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
            attn += self.n_heads * self.head_dim * d
        dense_ffn = 3 * d * self.d_ff
        if self.is_moe:
            expert = 3 * d * self.moe_d_ff
            moe_ffn = (self.n_routed_experts + self.n_shared_experts) * expert + d * self.n_routed_experts
            n_moe = L - self.first_dense_layers
            ffn_total = self.first_dense_layers * dense_ffn + n_moe * moe_ffn
        else:
            ffn_total = L * dense_ffn
        return emb + L * attn + ffn_total + 2 * L * d  # + norms

    def active_param_count(self) -> int:
        """Params touched per token (for MODEL_FLOPS = 6 * N_active * D)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        expert = 3 * d * self.moe_d_ff
        n_moe = L - self.first_dense_layers
        inactive = n_moe * (self.n_routed_experts - self.top_k) * expert
        return full - inactive


@dataclass(frozen=True)
class GNNConfig:
    """Message-passing GNNs (SpMM / triplet / irrep regimes)."""

    kind: str                     # gcn | graphsage | schnet | equiformer_v2
    n_layers: int
    d_hidden: int
    aggregator: str = "mean"
    # graphsage
    sample_sizes: Tuple[int, ...] = ()
    # gcn
    norm: str = "sym"
    # schnet
    n_rbf: int = 0
    cutoff: float = 0.0
    # equiformer
    l_max: int = 0
    m_max: int = 0
    n_heads: int = 0
    n_classes: int = 41
    dtype: str = "float32"


@dataclass(frozen=True)
class RecsysConfig:
    """Sparse-embedding + feature-interaction + MLP rankers."""

    kind: str                     # autoint
    n_sparse: int
    embed_dim: int
    n_attn_layers: int
    n_heads: int
    d_attn: int
    vocab_per_field: int = 1_000_000   # rows per embedding table
    mlp_dims: Tuple[int, ...] = (400, 400)
    multi_hot: int = 4                 # ids per field (EmbeddingBag regime)
    dtype: str = "float32"


ModelConfig = Any  # TransformerConfig | GNNConfig | RecsysConfig


# ---------------------------------------------------------------------------
# Arch spec (config + its own shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str              # lm | gnn | recsys
    model: ModelConfig
    shapes: Dict[str, Shape]
    source: str = ""         # provenance tag from the assignment
    notes: str = ""

    def shape(self, name: str) -> Shape:
        return self.shapes[name]


# Canonical LM shape set shared by the five LM archs (each arch re-instantiates
# so that a cell is (arch x its own shape object)).
def lm_shapes() -> Dict[str, LMShape]:
    return {
        "train_4k": LMShape("train_4k", seq_len=4_096, global_batch=256, kind="train"),
        "prefill_32k": LMShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
        "decode_32k": LMShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
        "long_500k": LMShape("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
    }


def gnn_shapes() -> Dict[str, GraphShape]:
    return {
        "full_graph_sm": GraphShape(
            "full_graph_sm", kind="full_graph", n_nodes=2_708, n_edges=10_556, d_feat=1_433
        ),
        "minibatch_lg": GraphShape(
            "minibatch_lg", kind="minibatch", n_nodes=232_965, n_edges=114_615_892,
            d_feat=602, batch_nodes=1_024, fanout=(15, 10),
        ),
        "ogb_products": GraphShape(
            "ogb_products", kind="full_graph", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100
        ),
        "molecule": GraphShape(
            "molecule", kind="batched", n_nodes=30, n_edges=64, batch_graphs=128, d_feat=0
        ),
    }


def recsys_shapes() -> Dict[str, RecsysShape]:
    return {
        "train_batch": RecsysShape("train_batch", kind="train", batch=65_536),
        "serve_p99": RecsysShape("serve_p99", kind="serve", batch=512),
        "serve_bulk": RecsysShape("serve_bulk", kind="serve", batch=262_144),
        "retrieval_cand": RecsysShape(
            "retrieval_cand", kind="retrieval", batch=1, n_candidates=1_000_000
        ),
    }


def reduced(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    """Small-config derivation for smoke tests (same family, tiny dims)."""
    return dataclasses.replace(cfg, **overrides)
