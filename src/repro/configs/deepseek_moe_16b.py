"""deepseek-moe-16b [moe] 28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400.

MoE 64e top-6, 2 shared + 64 routed, fine-grained [arXiv:2401.06066; hf].
Layer 0 is dense (d_ff = 10944 upstream; the assignment pins d_ff=1408 which is
the per-expert hidden -- we use 8*1408 for the first dense layer, the
fine-grained convention).
"""
from repro.configs.base import ArchSpec, TransformerConfig, lm_shapes

ARCH = ArchSpec(
    name="deepseek-moe-16b",
    family="lm",
    model=TransformerConfig(
        n_layers=28,
        d_model=2_048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=8 * 1_408,          # first dense layer
        moe_d_ff=1_408,          # per-expert (fine-grained)
        vocab_size=102_400,
        n_routed_experts=64,
        n_shared_experts=2,
        top_k=6,
        first_dense_layers=1,
        rope_theta=10_000.0,
        fsdp=True,
        grad_accum=2,
    ),
    shapes=lm_shapes(),
    source="arXiv:2401.06066; hf",
)
