"""schnet [gnn] n_interactions=3 d_hidden=64 rbf=300 cutoff=10.

[arXiv:1706.08566; paper]
"""
from repro.configs.base import ArchSpec, GNNConfig, gnn_shapes

ARCH = ArchSpec(
    name="schnet",
    family="gnn",
    model=GNNConfig(
        kind="schnet",
        n_layers=3,
        d_hidden=64,
        n_rbf=300,
        cutoff=10.0,
    ),
    shapes=gnn_shapes(),
    source="arXiv:1706.08566; paper",
)
