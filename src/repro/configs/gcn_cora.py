"""gcn-cora [gnn] n_layers=2 d_hidden=16 aggregator=mean norm=sym.

[arXiv:1609.02907; paper]
"""
from repro.configs.base import ArchSpec, GNNConfig, gnn_shapes

ARCH = ArchSpec(
    name="gcn-cora",
    family="gnn",
    model=GNNConfig(
        kind="gcn",
        n_layers=2,
        d_hidden=16,
        aggregator="mean",
        norm="sym",
        n_classes=7,
    ),
    shapes=gnn_shapes(),
    source="arXiv:1609.02907; paper",
)
