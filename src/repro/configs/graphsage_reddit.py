"""graphsage-reddit [gnn] n_layers=2 d_hidden=128 aggregator=mean sample_sizes=25-10.

[arXiv:1706.02216; paper]
"""
from repro.configs.base import ArchSpec, GNNConfig, gnn_shapes

ARCH = ArchSpec(
    name="graphsage-reddit",
    family="gnn",
    model=GNNConfig(
        kind="graphsage",
        n_layers=2,
        d_hidden=128,
        aggregator="mean",
        sample_sizes=(25, 10),
    ),
    shapes=gnn_shapes(),
    source="arXiv:1706.02216; paper",
)
