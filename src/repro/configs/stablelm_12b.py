"""stablelm-12b [dense] 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b; hf] -- dims follow the assignment exactly.
StableLM-2 uses partial rotary embeddings upstream; we use full rotary with
theta=10k (assignment gives no rotary spec) and note it here.
"""
from repro.configs.base import ArchSpec, TransformerConfig, lm_shapes

ARCH = ArchSpec(
    name="stablelm-12b",
    family="lm",
    model=TransformerConfig(
        n_layers=40,
        d_model=5_120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=160,  # d_model / n_heads
        d_ff=13_824,
        vocab_size=100_352,
        rope_theta=10_000.0,
        fsdp=True,
        grad_accum=4,
    ),
    shapes=lm_shapes(),
    source="hf:stabilityai/stablelm-2-1_6b; hf",
)
