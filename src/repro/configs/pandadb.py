"""PandaDB deployment config: the paper's own system knobs (§IV-§VI)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class VectorIndexConfig:
    """IVF-Flat per Algorithm 2: ~1 bucket per `vectors_per_bucket` vectors."""

    dim: int = 128
    metric: str = "l2"            # l2 | ip | cosine
    vectors_per_bucket: int = 100_000   # paper's empirical value
    min_buckets: int = 4
    nprobe: int = 8               # buckets scanned per query
    kmeans_iters: int = 8         # batch-build refinement steps
    block_n: int = 512            # ivf_scan kernel tile; gathered corpora are
    #                               padded to a multiple for stable shapes
    pending_compact_frac: float = 0.1   # compact append buffers once pending
    #                                     rows exceed this fraction of N
    pending_compact_min: int = 1024     # ... but never before this many
    # -- product quantization (IVF-PQ mode) --
    pq_m: int = 0                 # subspaces per vector; 0 = IVF-Flat (no PQ).
    #                               dim % pq_m must be 0 when enabled
    pq_bits: int = 8              # bits per code -> K = 2**bits centers per
    #                               subspace (8 keeps the ADC kernel MXU-wide)
    pq_kmeans_iters: int = 6      # per-subspace codebook refinement steps
    rerank_mult: int = 8          # ADC candidate fanout: scan keeps k' =
    #                               rerank_mult * k codes, exact re-rank
    #                               against original vectors returns top-k
    #                               (recall@10 >= 0.95 on clustered corpora)
    pq_residual: bool = False     # quantize vector - centroid[bucket] instead
    #                               of the raw vector: residuals are smaller
    #                               and better centered, so the same codebook
    #                               budget yields tighter ADC ordering (and a
    #                               smaller rerank_mult holds recall).  Scores
    #                               decompose as LUT sum + per-row bias +
    #                               per-query centroid term (see pq_scan/ref)


@dataclass(frozen=True)
class BlobStoreConfig:
    """BLOB metadata/content separation (§VI-A, Fig 5)."""

    inline_threshold: int = 10 * 1024  # <10kB stored inline as long-string
    table_columns: int = 64            # BLOB-table columns (row=id/|col|, col=id%|col|)
    metadata_bytes: int = 29           # length + mime + id (paper: "28.5 bytes")


@dataclass(frozen=True)
class CacheConfig:
    """Semantic-information cache keyed by (item, subprop, model serial)."""

    capacity_items: int = 1_000_000
    eviction: str = "lru"


@dataclass(frozen=True)
class AIPMConfig:
    """AI-model interactive protocol: async batched extractor dispatch."""

    max_batch: int = 256
    max_inflight: int = 4          # bounded async queue depth (backpressure)
    timeout_ms: int = 30_000
    workers: int = 2               # model-service parallelism (φ batches in flight)
    prefetch_depth: int = 2        # chunks of φ work submitted ahead of the
    #                                semantic filter's consumption point; 0 = sync
    auto_batch: bool = True        # size φ slices from observed avg_speed
    target_batch_s: float = 0.05   # auto_batch aims one slice ≈ this long


@dataclass(frozen=True)
class CostModelConfig:
    """Operator-speed statistics (§V-B): EWMA over observed per-row times."""

    ewma_alpha: float = 0.3
    default_structured_speed: float = 1e-7   # s/row prior
    default_semantic_speed: float = 0.3      # s/row prior (paper: 0.3s/face)
    default_knn_scan_speed: float = 2e-9     # s per corpus row scanned (prior;
    #                                          replaced by observed throughput)
    default_pq_scan_speed: float = 5e-10     # s per code row ADC-scanned
    #                                          (prior; the uint8 scan is
    #                                          bandwidth-bound, ~4-8x the
    #                                          float throughput)
    default_fused_scan_speed: float = 5e-10  # s per code row of the fused
    #                                          probe->ADC->top-k scan (prior
    #                                          only: choose_knn_scan never
    #                                          picks fused before observing
    #                                          a real measurement)
    shard_dispatch_s: float = 1e-4           # fixed cost of scattering one
    #                                          statement/scan to one shard
    #                                          (ctx setup + queueing); the
    #                                          fan-out term routed plans
    #                                          avoid
    # -- replica sets (§VII-A replication) --
    default_replica_read_s: float = 5e-3     # per-read latency prior until a
    #                                          replica has been measured
    hedge_quantile: float = 0.5              # latency quantile the hedge
    #                                          deadline is derived from (the
    #                                          median stays honest even when
    #                                          a minority of reads are
    #                                          fault-slowed; p9x would learn
    #                                          the outliers it should mask)
    hedge_deadline_mult: float = 3.0         # deadline = quantile * mult
    hedge_floor_s: float = 5e-3              # minimum deadline (cold start /
    #                                          very fast shards: don't hedge
    #                                          on scheduler noise)
    # -- proxy-first φ cascades (ROADMAP item 3) --
    default_proxy_scan_speed: float = 1e-5   # s/row prior for the cheap proxy
    #                                          scorer (replaced by observed
    #                                          throughput via record_proxy_scan)
    default_escalation_frac: float = 0.35    # fraction of rows expected to
    #                                          fall in [lo, hi] and escalate to
    #                                          the exact φ before any cascade
    #                                          has been observed
    # -- deadline-driven degradation ladder --
    accuracy_relax_notch: float = 0.05       # one ladder step lowers a
    #                                          cascade's WITH ACCURACY target
    #                                          by this much (never below
    #                                          accuracy_relax_floor)
    accuracy_relax_floor: float = 0.5


@dataclass(frozen=True)
class ClusterConfig:
    """Sharded serving (§VII-A): property + unstructured data partitioned
    by stable node-id hash, graph structure + index metadata replicated."""

    n_shards: int = 1
    parallel_fanout: bool = True   # scatter shard scans on a thread pool
    #                                (results are merged in shard order, so
    #                                output is deterministic either way)
    merge_batch_rows: int = 256    # coordinator's ordered-merge chunk size
    # -- self-healing replication (ReplicatedPandaDB) --
    replication: int = 1           # replicas per shard (1 = no replica sets)
    hedge_reads: bool = True       # race a second replica once a read leg
    #                                misses the latency-quantile deadline
    #                                (first responder wins, loser closed)
    read_retries: int = 2          # transient-error retries per read leg
    #                                before failing over to another replica
    retry_backoff_s: float = 0.002  # linear backoff between retries
    split_rerank_budget: bool = False  # divide the global re-rank candidate
    #                                budget ceil(rerank_mult/P) per shard so
    #                                total exact-re-rank work stays constant
    #                                as P grows (pair with pq_residual=True:
    #                                tighter ADC ordering keeps the smaller
    #                                per-shard pools exact in practice)
    rebalance_skew: float = 2.0    # max/mean owned-rows ratio above which
    #                                the Rebalancer proposes moves
    # -- end-to-end deadlines --
    default_deadline_ms: int = 0   # per-query budget applied when run() names
    #                                none; 0 = queries have no deadline
    close_drain_s: float = 2.0     # close() budget for draining in-flight
    #                                hedge legs (was a hard-coded wait(2.0))
    # -- per-replica circuit breakers --
    breaker_failures: int = 2      # consecutive failures (or slow calls) that
    #                                flip a replica's breaker open; <= read
    #                                retries so a flapping replica fails over
    #                                inside one statement's retry budget
    breaker_reset_s: float = 0.25  # open -> half-open cool-down before one
    #                                timed probe is allowed through
    breaker_slow_call_s: float = 0.0   # reads slower than this count as
    #                                failures (0 = slow-call tracking off)


@dataclass(frozen=True)
class CascadeConfig:
    """Proxy-first φ cascades: accuracy-targeted semantic predicates."""

    calibration_sample: int = 128   # blobs sampled for threshold fitting
    calibration_pairs: int = 1024   # (i, j) score/label pairs drawn from them
    calibration_seed: int = 0       # deterministic sampling (shard parity)
    min_curve_pairs: int = 16       # below this the calibrator refuses to fit
    #                                 (escalate everything instead of guessing)


@dataclass(frozen=True)
class ServingConfig:
    """QueryServer admission control: shed early, degrade gracefully,
    never let an unbounded queue turn overload into collapse."""

    queue_depth: int = 0            # bounded request queue; 0 = unbounded
    #                                 (the seed's behavior)
    admission_policy: str = "reject"   # queue-full policy: "reject" bounces
    #                                 the new request, "drop_oldest" evicts
    #                                 the request that has waited longest
    #                                 (it is the most likely to be expired)
    default_deadline_ms: int = 0    # budget stamped on requests that name
    #                                 none at submit(); 0 = no deadline
    shed_on_arrival: bool = True    # refuse requests whose estimated queue
    #                                 wait + service time already exceeds
    #                                 their remaining budget (only requests
    #                                 carrying a deadline are ever shed)


@dataclass(frozen=True)
class ObsConfig:
    """Observability: query tracing, metrics export, slow-query log."""

    trace: bool = False             # per-query span trees; OFF by default —
    #                                 disabled tracing must cost near-zero
    #                                 (gated by bench_obs_overhead.py)
    trace_keep_last: bool = True    # tracer keeps the most recent Trace for
    #                                 inspection (tracer.last)
    slow_query_ms: float = 0.0      # serving engine writes a JSON line for
    #                                 queries slower than this; 0 = off
    slow_query_log: str = ""        # path of the JSON-lines slow-query log
    #                                 ("" with slow_query_ms > 0 = stderr)


@dataclass(frozen=True)
class PandaDBConfig:
    index: VectorIndexConfig = field(default_factory=VectorIndexConfig)
    blob: BlobStoreConfig = field(default_factory=BlobStoreConfig)
    cache: CacheConfig = field(default_factory=CacheConfig)
    aipm: AIPMConfig = field(default_factory=AIPMConfig)
    cost: CostModelConfig = field(default_factory=CostModelConfig)
    cluster: ClusterConfig = field(default_factory=ClusterConfig)
    cascade: CascadeConfig = field(default_factory=CascadeConfig)
    serving: ServingConfig = field(default_factory=ServingConfig)
    obs: ObsConfig = field(default_factory=ObsConfig)
    # distributed layout (§VII-A): structure replicated, properties sharded
    replicate_graph_structure: bool = True
    shard_axis: str = "data"


DEFAULT = PandaDBConfig()
