"""Fault tolerance & elasticity (design target: 1000+ nodes).

Three mechanisms, mirroring the paper's cluster semantics (§VII-A):

1. **Checkpoint/restart** -- versioned manifests (checkpoint.py).  On any
   failure the job restarts from `latest_version`; graph-store mutations
   since the checkpoint replay from the WAL (graphstore/wal.py), exactly the
   paper's "execute query statements in the local log until the version is
   consistent".

2. **Elastic re-mesh** -- `elastic_restart` re-factorizes the surviving
   device count into a (data, model) mesh, rebuilds shardings from the SAME
   logical axis rules, and device_puts the restored host state.  Because all
   sharding is rule-driven (distributed/sharding.py), no model code changes.

3. **Straggler mitigation** -- `StragglerMonitor` tracks per-step latencies;
   a host whose EWMA exceeds `threshold x` median is flagged for the
   scheduler to drain (on TPU pods slow hosts are replaced, not worked
   around, since SPMD steps are synchronous); the data pipeline additionally
   over-provisions micro-shards so a re-assigned host can catch up by
   skipping (deterministic work stealing).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.distributed.sharding import ShardingRules, tree_shardings
from repro.launch.mesh import make_mesh_for
from repro.training.checkpoint import CheckpointManager


def elastic_restart(ckpt: CheckpointManager, like_state,
                    rules_fn: Callable[[Any], ShardingRules],
                    axes_tree, n_devices: int, model_parallel: int = 1):
    """Restore the latest checkpoint onto a fresh mesh of `n_devices`.

    rules_fn(mesh) -> ShardingRules must be the same rule builder used at
    launch; axes_tree is the logical-axis pytree for the state."""
    mesh = make_mesh_for(n_devices, model_parallel=model_parallel)
    rules = rules_fn(mesh)
    shardings = tree_shardings(mesh, rules, axes_tree)
    state, version = ckpt.restore(like_state, shardings=shardings)
    return mesh, rules, state, version


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    threshold: float = 1.5
    alpha: float = 0.3
    ewma: Optional[np.ndarray] = None

    def record(self, host_times: np.ndarray) -> List[int]:
        """Feed per-host step latencies; returns hosts flagged as stragglers."""
        host_times = np.asarray(host_times, np.float64)
        if self.ewma is None:
            self.ewma = host_times.copy()
        else:
            self.ewma = self.alpha * host_times + (1 - self.alpha) * self.ewma
        med = float(np.median(self.ewma))
        return [i for i, t in enumerate(self.ewma)
                if med > 0 and t > self.threshold * med]


@dataclasses.dataclass
class RetryPolicy:
    max_restarts: int = 100
    backoff_s: float = 5.0

    def run(self, step_fn: Callable[[], Any],
            on_failure: Callable[[Exception], None]) -> Any:
        """Supervision loop: run until success or restart budget exhausted."""
        for attempt in range(self.max_restarts):
            try:
                return step_fn()
            except Exception as e:  # noqa: BLE001
                on_failure(e)
                time.sleep(min(self.backoff_s * (attempt + 1), 60.0))
        raise RuntimeError(f"exceeded {self.max_restarts} restarts")
