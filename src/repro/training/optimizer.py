"""Sharded AdamW with ZeRO-style state placement.

The first/second moments are fp32 pytrees with the *same logical axes* as the
parameters, so FSDP-sharded params get FSDP-sharded optimizer state for free
(ZeRO-2/3 semantics under GSPMD).  No fp32 master copy is kept: updates are
computed in fp32 and cast back to the param dtype -- this is the memory layout
that lets deepseek-v2-236b train on 256 chips (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes) -> Dict[str, Any]:
    return {"m": param_axes, "v": param_axes, "step": None}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def adamw_update(grads, opt_state, params, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
