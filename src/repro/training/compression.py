"""Gradient compression for the pod (DCN-crossing) axis.

int8 uniform quantization with per-leaf scale and error feedback (1-bit Adam
family): the quantization residual is carried to the next step, so the
compressed all-reduce is unbiased over time.  Used by the multi-pod train
step for cross-pod gradient sync -- the within-pod reduction stays bf16 over
ICI; only the slow pod axis pays the 4x smaller payload.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, error_fb) -> Tuple[Any, Any, Any]:
    """Returns (q_int8 tree, scales tree, new corrected grads tree)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_e = gf - deq
        return q, scale, new_e

    qs, scales, errs = [], [], []
    leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(error_fb)
    for g, e in zip(leaves, e_leaves):
        q, s, ne = one(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            treedef.unflatten(errs))


def decompress(q_tree, scale_tree) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, scale_tree)


def compressed_psum(grads, error_fb, axis_name: str) -> Tuple[Any, Any]:
    """All-reduce int8 payloads over `axis_name` (inside shard_map/pmap),
    averaging after decompression.  Returns (synced grads, new error_fb)."""
    q, s, new_e = compress(grads, error_fb)
    # sum int8 payloads in int32 to avoid overflow, scale per-participant
    summed = jax.tree.map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q)
    n = jax.lax.psum(1, axis_name)
    synced = jax.tree.map(
        lambda sq, ss: sq.astype(jnp.float32) * ss / n, summed, s)
    return synced, new_e


def compression_ratio(grads) -> float:
    """Payload ratio int8+scale vs fp32 (reporting helper)."""
    total_f32 = sum(g.size * 4 for g in jax.tree.leaves(grads))
    total_q = sum(g.size * 1 + 4 for g in jax.tree.leaves(grads))
    return total_q / total_f32
