"""Training driver: jit'd step + checkpointing + WAL versioning + restart.

Used by examples/train_lm_e2e.py and launch/train.py.  On the CPU container
this trains reduced configs end-to-end; on a pod the same loop runs the
production bundles from launch/steps.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import StragglerMonitor
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainLoopConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    restore: bool = True


def run_train_loop(loss_fn: Callable, params: Any, batches: Iterator[Dict],
                   cfg: TrainLoopConfig,
                   opt_cfg: Optional[AdamWConfig] = None,
                   meta: Optional[Dict] = None) -> Dict[str, Any]:
    """Generic loop: loss_fn(params, batch) -> scalar loss."""
    opt_cfg = opt_cfg or AdamWConfig()
    opt_state = init_opt_state(params)
    start_step = 0
    ckpt = CheckpointManager(cfg.ckpt_dir) if cfg.ckpt_dir else None
    if ckpt and cfg.restore and ckpt.latest_version() is not None:
        (params, opt_state), start_step = ckpt.restore((params, opt_state))
        print(f"[train] restored version {start_step}")

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, loss, om["grad_norm"]

    history = []
    t_start = time.perf_counter()
    it = iter(batches)
    for i in range(start_step, cfg.n_steps):
        batch = next(it)
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, loss, gnorm = step(params, opt_state, batch)
        if i % cfg.log_every == 0 or i == cfg.n_steps - 1:
            l = float(loss)
            history.append({"step": i, "loss": l, "grad_norm": float(gnorm)})
            print(f"[train] step {i} loss {l:.4f} gnorm {float(gnorm):.3f}")
        if ckpt and ((i + 1) % cfg.ckpt_every == 0 or i == cfg.n_steps - 1):
            ckpt.save(i + 1, (params, opt_state), meta=meta)
    wall = time.perf_counter() - t_start
    return {"params": params, "opt_state": opt_state, "history": history,
            "wall_s": wall, "final_loss": history[-1]["loss"] if history else None}
