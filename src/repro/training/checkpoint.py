"""Versioned checkpointing (paper §VII-A write-log semantics).

Every checkpoint carries a monotonically increasing ``version`` (the train
step == the paper's writing-query version number).  A manifest records the
version, arch, mesh factorization and leaf tree structure; restore loads to
host and re-shards onto WHATEVER mesh the restarted job has -- the elastic
path (shrunken mesh after node failure) is `restore(..., mesh=new_mesh)`.

Layout:
  <dir>/manifest.json            latest-version pointer + history
  <dir>/step_<v>/manifest.json   per-checkpoint metadata
  <dir>/step_<v>/arrays.npz      flattened leaves (host copy)
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save -------------------------------------------------------------------

    def save(self, version: int, state: Dict[str, Any],
             meta: Optional[Dict[str, Any]] = None) -> Path:
        step_dir = self.dir / f"step_{version}"
        tmp = self.dir / f".tmp_step_{version}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten_with_paths(state)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "version": version,
            "time": time.time(),
            "keys": sorted(arrays.keys()),
            "meta": meta or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp.rename(step_dir)                       # atomic publish
        self._update_root(version)
        self._gc()
        return step_dir

    def _update_root(self, version: int) -> None:
        root = {"latest": version,
                "history": sorted(self.versions())}
        (self.dir / "manifest.json").write_text(json.dumps(root, indent=1))

    def _gc(self) -> None:
        vs = sorted(self.versions())
        for v in vs[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{v}", ignore_errors=True)
        if vs:
            self._update_root(vs[-1])

    # -- restore ----------------------------------------------------------------

    def versions(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")]

    def latest_version(self) -> Optional[int]:
        vs = self.versions()
        return max(vs) if vs else None

    def restore(self, like: Dict[str, Any], version: Optional[int] = None,
                shardings: Optional[Any] = None
                ) -> Tuple[Dict[str, Any], int]:
        """Load into the structure of `like`; optionally device_put with new
        shardings (elastic re-mesh restore)."""
        version = version if version is not None else self.latest_version()
        if version is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step_dir = self.dir / f"step_{version}"
        data = np.load(step_dir / "arrays.npz")
        flat_like = _flatten_with_paths(like)
        leaves = {}
        for key in flat_like:
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            leaves[key] = data[key]
        # rebuild tree in `like` order
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        keys = ["/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                         for p in path) for path, _ in paths]
        new_leaves = [leaves[k] for k in keys]
        state = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state, version

    def meta(self, version: Optional[int] = None) -> Dict[str, Any]:
        version = version if version is not None else self.latest_version()
        return json.loads(
            (self.dir / f"step_{version}" / "manifest.json").read_text())
