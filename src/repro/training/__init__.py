from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_axes  # noqa: F401
