#!/usr/bin/env bash
# Tier-1 verify: the full test suite plus a quick serving-benchmark smoke.
#
#   scripts/verify.sh            # full tests + bench smoke
#   scripts/verify.sh --fast     # full tests only
#   scripts/verify.sh --quick    # tier-1 minus `slow` markers, no bench
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="${1:-}"

echo "== tier-1: pytest =="
if [[ "$MODE" == "--quick" ]]; then
  # fail fast on the cascade accuracy/parity suite, the cluster parity +
  # chaos/failover suites, the deadline/admission-control suite, the
  # observability suite (tracing parity + PROFILE + metrics views), and the
  # kNN hot path (batched index + PQ/ADC + kernel dispatch), then the rest
  # of the tier-1 suite minus `slow` markers
  python -m pytest -x -q tests/test_cascade.py \
      tests/test_cluster.py tests/test_replication.py \
      tests/test_overload.py tests/test_obs.py \
      tests/test_vector_index.py \
      tests/test_pq_index.py tests/test_kernels.py -m "not slow"
  python -m pytest -x -q -m "not slow" \
      --ignore=tests/test_cascade.py \
      --ignore=tests/test_cluster.py --ignore=tests/test_replication.py \
      --ignore=tests/test_overload.py --ignore=tests/test_obs.py \
      --ignore=tests/test_vector_index.py \
      --ignore=tests/test_pq_index.py --ignore=tests/test_kernels.py
else
  python -m pytest -x -q
fi

if [[ -z "$MODE" ]]; then
  echo
  echo "== bench smoke: prepared-statement serving throughput =="
  PYTHONPATH="src:.:${PYTHONPATH}" python benchmarks/bench_throughput.py --smoke
fi

echo
echo "verify OK${MODE:+ (${MODE#--})}"
