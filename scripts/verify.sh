#!/usr/bin/env bash
# Tier-1 verify: the full test suite plus a quick serving-benchmark smoke.
#
#   scripts/verify.sh            # tests + bench smoke
#   scripts/verify.sh --fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo
  echo "== bench smoke: prepared-statement serving throughput =="
  PYTHONPATH="src:.:${PYTHONPATH}" python benchmarks/bench_throughput.py --smoke
fi

echo
echo "verify OK"
