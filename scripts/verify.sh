#!/usr/bin/env bash
# Tier-1 verify: the full test suite plus a quick serving-benchmark smoke.
#
#   scripts/verify.sh            # full tests + bench smoke
#   scripts/verify.sh --fast     # full tests only
#   scripts/verify.sh --quick    # tier-1 minus `slow` markers, no bench
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="${1:-}"

echo "== tier-1: pytest =="
if [[ "$MODE" == "--quick" ]]; then
  python -m pytest -x -q -m "not slow"
else
  python -m pytest -x -q
fi

if [[ -z "$MODE" ]]; then
  echo
  echo "== bench smoke: prepared-statement serving throughput =="
  PYTHONPATH="src:.:${PYTHONPATH}" python benchmarks/bench_throughput.py --smoke
fi

echo
echo "verify OK${MODE:+ (${MODE#--})}"
